"""The WAMI stages as a measured PallasOracle backend (DESIGN.md §2).

Binds the knob-parameterized Pallas kernels under ``repro.kernels`` to
the COSMOS component names, registers WAMI with the App/Backend
registry (:mod:`repro.core.registry`), and keeps the classic session
constructors as thin wrappers over ``build_session("wami", "pallas")``:

  * seven stages are priced by *running* their kernel on a PLM-sized
    tile (``ports`` -> lane-bank grid columns, ``unrolls`` -> rows per
    grid step): debayer, grayscale, gradient, steepest-descent, Hessian,
    warp, change detection;
  * the 6x6 matrix stages (``sd_update``, ``matrix_*``) have no kernel
    worth measuring — a (6, 6) problem never leaves one VPU tile — and
    fall back to the analytical tool inside the same oracle, so the
    full Fig. 8 TMG explores end-to-end;
  * in CI there is no TPU and interpret-mode wall clocks are noise, so
    the default mode replays the recordings checked in under
    ``artifacts/measurements/`` through a
    :class:`~repro.core.pallas_oracle.MeasurementSet` (regenerate:
    ``python examples/wami_pallas.py --record [--tile N]``).

Inputs are baked deterministically per tile size so that record and
replay price the same physical workload.
"""

from __future__ import annotations

import os
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from ...core.hlsim import HLSTool
from ...core.pallas_oracle import (MeasurementSet, MeasurementStore,
                                   PallasKernelSpec, PallasOracle,
                                   open_recording)
from ...core.plm.units import UnitSystem, fit_unit_system
from ...core.registry import App, build_session, register_app
from ...core.session import ExplorationSession
from ...kernels import (wami_change_det, wami_debayer, wami_gradient,
                        wami_grayscale, wami_steep, wami_warp)
from . import components as C
from .knobs import WAMI_TILE_SIZES
from .pipeline import (MATRIX_INV_LATENCY_S, wami_hls_tool,
                       wami_knob_spaces, wami_plm_planner, wami_tmg)

__all__ = ["wami_pallas_components", "wami_pallas_oracle",
           "wami_pallas_session", "wami_unit_system", "wami_plm_session",
           "wami_measurement_set", "wami_parity_cases",
           "default_measurement_path", "WAMI_RECORDED_TILES"]

_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "..", ".."))

# tiles with a recording checked in under artifacts/measurements/ —
# interpret-mode walls, one store file per tile (ROADMAP: multi-tile
# recordings); sessions load only the native 128 by default so legacy
# walks keep their exact fallback-priced tile axis
WAMI_RECORDED_TILES = (64, 128, 256)


def default_measurement_path(tile: int = C.TILE) -> str:
    return os.path.join(_REPO_ROOT, "artifacts", "measurements",
                        f"wami_pallas_tile{tile}.json")


def wami_measurement_set(tiles: Sequence[int] = (C.TILE,),
                         *, flush_every: int = 0) -> MeasurementSet:
    """The checked-in WAMI recordings for ``tiles``, as one routing set."""
    return MeasurementSet.load(
        (default_measurement_path(t) for t in tiles),
        flush_every=flush_every)


def wami_pallas_components(tile: int = C.TILE
                           ) -> Dict[str, PallasKernelSpec]:
    """PallasKernelSpec per measurable WAMI stage, on a (tile, tile)
    PLM-resident frame tile with deterministic baked inputs."""
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 8)
    bayer = jax.random.uniform(ks[0], (tile, tile)) * 1023.0
    rgb = jax.random.uniform(ks[1], (tile, tile, 3)) * 255.0
    gray = jax.random.uniform(ks[2], (tile, tile)) * 255.0
    gx = jax.random.normal(ks[3], (tile, tile))
    gy = jax.random.normal(ks[4], (tile, tile))
    sd = jax.random.normal(ks[5], (tile, tile, 6))
    p = jnp.array([0.01, -0.005, 0.8, 0.004, -0.01, -0.6], jnp.float32)
    mu = gray[..., None] + jax.random.normal(ks[6], (tile, tile, 3)) * 8.0
    var = jnp.full((tile, tile, 3), 36.0, jnp.float32)
    w = jnp.full((tile, tile, 3), 1.0 / 3.0, jnp.float32)

    def bake(fn: Callable, *args) -> Callable:
        def build(ports: int, unrolls: int, interpret: bool):
            def run():
                return fn(*args, ports=ports, unrolls=unrolls,
                          use_pallas=True, interpret=interpret)
            return run
        return build

    shape = (tile, tile)
    return {
        "debayer": PallasKernelSpec(
            name="debayer", shape=shape,
            build=bake(wami_debayer.debayer, bayer),
            vmem_bytes=wami_debayer.vmem_bytes,
            grid_steps=wami_debayer.grid_steps, n_in=9, n_out=3),
        "grayscale": PallasKernelSpec(
            name="grayscale", shape=shape,
            build=bake(wami_grayscale.grayscale, rgb),
            vmem_bytes=wami_grayscale.vmem_bytes,
            grid_steps=wami_grayscale.grid_steps, n_in=3, n_out=1),
        "gradient": PallasKernelSpec(
            name="gradient", shape=shape,
            build=bake(wami_gradient.gradient, gray),
            vmem_bytes=wami_gradient.vmem_bytes,
            grid_steps=wami_gradient.grid_steps, n_in=4, n_out=2),
        "steep_descent": PallasKernelSpec(
            name="steep_descent", shape=shape,
            build=bake(wami_steep.steepest_descent, gx, gy),
            vmem_bytes=wami_steep.vmem_bytes,
            grid_steps=wami_steep.grid_steps, n_in=2, n_out=6),
        "hessian": PallasKernelSpec(
            name="hessian", shape=shape,
            build=bake(wami_steep.hessian, sd),
            vmem_bytes=wami_steep.hessian_vmem_bytes,
            grid_steps=wami_steep.grid_steps, n_in=6, n_out=1),
        "warp": PallasKernelSpec(
            name="warp", shape=shape,
            build=bake(wami_warp.warp_affine, gray, p),
            vmem_bytes=wami_warp.vmem_bytes,
            grid_steps=wami_warp.grid_steps, n_in=6, n_out=1),
        "change_det": PallasKernelSpec(
            name="change_det", shape=shape,
            build=bake(wami_change_det.change_detection, gray, mu, var, w),
            vmem_bytes=wami_change_det.vmem_bytes,
            grid_steps=wami_change_det.grid_steps, n_in=10, n_out=10),
    }


def wami_parity_cases(tile: int = C.TILE):
    """(name, pallas_fn, oracle_fn, args) per WAMI stage kernel — the
    interpret-mode parity gate's work list (kernels_micro)."""
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 7)
    bayer = jax.random.uniform(ks[0], (tile, tile)) * 1023.0
    rgb = jax.random.uniform(ks[1], (tile, tile, 3)) * 255.0
    gray = jax.random.uniform(ks[2], (tile, tile)) * 255.0
    gx = jax.random.normal(ks[3], (tile, tile))
    gy = jax.random.normal(ks[4], (tile, tile))
    sd = jax.random.normal(ks[5], (tile, tile, 6))
    # shear terms small enough that every source fraction stays in
    # ~[0.3, 0.7]: the floor() cell choice is then identical between the
    # two compiled programs, so parity is exact instead of flipping
    # gather cells at integer boundaries
    p = jnp.array([1 / 1024, -1 / 2048, 0.5, 1 / 2048, -1 / 1024, 0.5],
                  jnp.float32)
    mu = gray[..., None] + jax.random.normal(ks[6], (tile, tile, 3)) * 8.0
    var = jnp.full((tile, tile, 3), 36.0)
    w = jnp.full((tile, tile, 3), 1.0 / 3.0)
    return [
        ("wami_debayer", wami_debayer.debayer, wami_debayer.debayer_oracle,
         (bayer,)),
        ("wami_grayscale", wami_grayscale.grayscale,
         wami_grayscale.grayscale_oracle, (rgb,)),
        ("wami_gradient", wami_gradient.gradient,
         wami_gradient.gradient_oracle, (gray,)),
        ("wami_steep", wami_steep.steepest_descent,
         wami_steep.steepest_descent_oracle, (gx, gy)),
        ("wami_hessian", wami_steep.hessian, wami_steep.hessian_oracle,
         (sd,)),
        ("wami_warp", wami_warp.warp_affine, wami_warp.warp_affine_oracle,
         (gray, p)),
        ("wami_change_det", wami_change_det.change_detection,
         wami_change_det.change_detection_oracle, (gray, mu, var, w)),
    ]


def wami_pallas_oracle(mode: str = "replay", *, tile: int = C.TILE,
                       store: Optional[MeasurementStore] = None,
                       store_path: Optional[str] = None,
                       measurements: Optional[MeasurementSet] = None,
                       fallback: Optional[HLSTool] = None,
                       interpret: bool = True,
                       flush_every: int = 16,
                       timer=None, **kwargs) -> PallasOracle:
    """The measured WAMI oracle.  Default: deterministic replay from the
    checked-in recording (CI-safe, no TPU).  Record mode flushes the
    store every ``flush_every`` timings through the atomic rename
    protocol and resumes from whatever an interrupted campaign already
    flushed — killed recordings never re-pay for timed points."""
    if measurements is None and mode in ("record", "replay"):
        if store is not None:
            measurements = MeasurementSet.from_store(store, tile=tile)
        else:
            measurements = open_recording(
                store_path or default_measurement_path(tile), mode=mode,
                tile=tile, interpret=interpret, flush_every=flush_every)
    return PallasOracle(wami_pallas_components(tile), mode=mode,
                        measurements=measurements,
                        components_factory=wami_pallas_components,
                        fallback=fallback or wami_hls_tool(),
                        interpret=interpret, timer=timer,
                        native_tile=tile,
                        record_hint=f"re-record with `python examples/"
                                    f"wami_pallas.py --record --tile {tile}`",
                        **kwargs)


def wami_pallas_session(delta: float = 0.25, *, mode: str = "replay",
                        tile: int = C.TILE, workers: int = 1,
                        oracle: Optional[PallasOracle] = None,
                        **kwargs) -> ExplorationSession:
    """An :class:`ExplorationSession` over the WAMI TMG driven by the
    measured backend — ``build_session("wami", "pallas")`` with the
    classic signature (same phases, ledger semantics, and knob spaces
    as :func:`~repro.apps.wami.pipeline.wami_session`)."""
    tool = oracle or wami_pallas_oracle(mode, tile=tile)
    return build_session("wami", "pallas", tool=tool, delta=delta,
                         workers=workers, **kwargs)


def wami_unit_system(tile: int = C.TILE,
                     store: Optional[MeasurementStore] = None
                     ) -> UnitSystem:
    """Exchange rates fitted from the checked-in recording: per-component
    latency scales plus one global bytes-per-mm² area rate.  Derived
    from the store's sorted entries and the deterministic VMEM/area
    formulas — byte-reproducible on any machine holding the recording."""
    store = store or MeasurementStore.load(default_measurement_path(tile))
    return fit_unit_system(store, wami_pallas_components(tile),
                           wami_hls_tool())


def wami_plm_session(delta: float = 0.25, *, tile: int = C.TILE,
                     tile_sizes: Optional[tuple] = (64, 128),
                     measured_tiles: Sequence[int] = (C.TILE,),
                     workers: int = 1, share_plm: bool = True,
                     **kwargs) -> ExplorationSession:
    """The memory-co-design WAMI drive on the checked-in recordings.

    Everything the PLM subsystem adds, wired together (docs/memory.md):

      * the tile knob is a third axis on the tile-scaled components —
        tiles with a recording in ``measured_tiles`` replay measured
        walls through the :class:`MeasurementSet`, other tiles are
        priced by the unit-calibrated analytical fallback
        (``missing="fallback"`` also covers mapped unrolls the recorded
        walk never touched, so the drive stays deterministic and
        machine-free);
      * the fallback reports measured-axis latencies and VMEM-byte areas
        (:func:`wami_unit_system`), so the mixed system front is
        unit-clean;
      * the map phase prices the memory subsystem through the PLM
        planner: the TMG certifies the six LK-loop components mutually
        exclusive and their PLMs become one shared multi-bank memory.

    ``measured_tiles`` defaults to just the native 128 so the classic
    drive stays byte-identical to the single-store era; pass e.g.
    ``(64, 128)`` to replay the tile-64 recording instead of pricing
    that ladder through the fallback (WAMI_RECORDED_TILES lists what is
    on disk).  ``tile_sizes`` defaults to (64, 128) rather than the
    analytical variant's full ``WAMI_TILE_SIZES`` for the same reason:
    the axis stays anchored where measurements exist.
    """
    store = MeasurementStore.load(default_measurement_path(tile))
    units = wami_unit_system(tile, store=store)
    fallback = units.calibrated(wami_hls_tool())
    measurements = MeasurementSet.from_store(store, tile=tile)
    for extra in measured_tiles:
        if extra != tile:
            measurements.add(MeasurementStore.load(
                default_measurement_path(extra)))
    oracle = PallasOracle(wami_pallas_components(tile), mode="replay",
                          measurements=measurements,
                          components_factory=wami_pallas_components,
                          fallback=fallback,
                          native_tile=tile, missing="fallback",
                          record_hint=f"re-record with `python examples/"
                                      f"wami_pallas.py --record --tile "
                                      f"{tile}`")
    # an explicitly empty tile_sizes means "no tile axis" — pass () so
    # build_session does NOT substitute the app's measured default
    return build_session("wami", "pallas", tool=oracle, delta=delta,
                         share_plm=share_plm,
                         tile_sizes=tuple(tile_sizes or ()),
                         workers=workers, **kwargs)


# ----------------------------------------------------------------------
# registration: `get_app("wami")` resolves to this record
# ----------------------------------------------------------------------
register_app(App(
    name="wami",
    description="WAMI Lucas-Kanade + change detection (the paper's "
                "Fig. 8 case study): 12 HLS components + 1 software stage",
    tmg=wami_tmg,
    knob_spaces=wami_knob_spaces,
    analytical=wami_hls_tool,
    fixed={"matrix_inv": MATRIX_INV_LATENCY_S},
    delta=0.25,
    kernel_specs=wami_pallas_components,
    native_tile=C.TILE,
    measurement_path=default_measurement_path,
    recorded_tiles=WAMI_RECORDED_TILES,
    default_tiles=(C.TILE,),
    calibrated_fallback=lambda store=None: wami_unit_system(
        store=store).calibrated(wami_hls_tool()),
    record_hint="re-record with `python examples/wami_pallas.py "
                "--record [--tile N]`",
    plm_planner=wami_plm_planner,
    plm_tile_sizes=WAMI_TILE_SIZES,
    plm_tile_sizes_measured=(64, 128),
    parity_cases=wami_parity_cases,
))
