"""Fleet: the hybrid attention + SSD pipeline, the second registered
COSMOS app (``get_app("fleet")``)."""

from .pipeline import (FLASH_D, FLASH_HEADS, FLASH_S, SSD_MAX_HEADS, SSD_N,
                       SSD_P, SSD_S, default_measurement_path,
                       fleet_calibrated_tool, fleet_kernel_specs,
                       fleet_knob_spaces, fleet_pallas_oracle,
                       fleet_parity_cases, fleet_session, fleet_tmg,
                       fleet_unit_system, fleet_xla_tool)

__all__ = [
    "FLASH_S", "FLASH_D", "FLASH_HEADS", "SSD_S", "SSD_P", "SSD_N",
    "SSD_MAX_HEADS", "fleet_tmg", "fleet_knob_spaces", "fleet_xla_tool",
    "fleet_kernel_specs", "fleet_pallas_oracle", "fleet_calibrated_tool",
    "fleet_unit_system", "fleet_session", "fleet_parity_cases",
    "default_measurement_path",
]
