"""The fleet application: a hybrid attention + SSD serving pipeline.

The first non-WAMI workload to run the full COSMOS path (characterize ->
LP -> map -> PLM plan), registered as ``get_app("fleet")``.  The system
is a two-stage ML pipeline — a flash-attention stage feeding an SSD
(Mamba2) scan stage, the attention/SSM hybrid split — and it is priced
by BOTH oracle families:

  * **analytical** — :class:`~repro.core.xlatool.XLATool` over
    (ModelConfig, ShapeSpec) stages: ``ports`` is the stage's fleet
    share (chips), ``unrolls`` the inverse microbatching, cost the
    total HBM claimed (the paper's area);
  * **pallas (calibrated-measured)** — the same two stages as
    :class:`~repro.core.pallas_oracle.PallasKernelSpec`s over the real
    ``kernels/flash_attention`` and ``kernels/ssd_scan`` Pallas
    kernels.  ``ports`` maps onto the kernels' *parallel* grid
    dimension (Q-block columns for attention, head lanes for the SSD
    scan) and ``unrolls`` onto the sequential block depth (KV rows /
    chunk length per grid step) — the same lane-bank reading DESIGN.md
    §2 gives the WAMI kernels.  Interpret-mode walls are recorded under
    ``artifacts/measurements/`` and the XLA roofline's constants are
    fitted to them through :mod:`repro.core.calibrate`
    (:func:`fleet_calibrated_tool`), so the analytical fallback prices
    on the measured axes.

The pipeline TMG uses single-buffer channels: adjacent stages serialize
(Fig. 3 with buffers=1), which the PLM planner's TMG certificate turns
into a shared-memory opportunity — the two stages may time-multiplex
one VMEM pool, exactly the cross-component sharing WAMI's LK loop gets.
"""

from __future__ import annotations

import os
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from ...configs import SHAPES, get_config
from ...core.knobs import KnobSpace
from ...core.pallas_oracle import (MeasurementSet, MeasurementStore,
                                   PallasKernelSpec, PallasOracle,
                                   open_recording)
from ...core.plm.planner import PLMPlanner
from ...core.plm.units import UnitSystem, fit_unit_system
from ...core.registry import App, build_session, register_app
from ...core.session import ExplorationSession
from ...core.tmg import TMG, pipeline_tmg
from ...core.xlatool import XLATool
from ...kernels.flash_attention import mha, mha_ref
from ...kernels.ssd_scan import ssd, ssd_oracle

__all__ = ["FLASH_S", "FLASH_D", "FLASH_HEADS", "SSD_S", "SSD_P", "SSD_N",
           "SSD_MAX_HEADS", "fleet_tmg", "fleet_knob_spaces",
           "fleet_xla_tool", "fleet_kernel_specs", "fleet_pallas_oracle",
           "fleet_calibrated_tool", "fleet_unit_system", "fleet_session",
           "fleet_parity_cases", "default_measurement_path"]

_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "..", ".."))

# measured-kernel geometry: small enough that interpret-mode recording
# is minutes, large enough that every knob point changes the grid
FLASH_S = 128          # Sq == Skv tokens per attention launch
FLASH_D = 64           # head dim
FLASH_HEADS = 2        # query heads (GQA 2:1 onto one KV head)
SSD_S = 256            # scan length per launch
SSD_P = 64             # SSD head dim
SSD_N = 64             # SSD state dim
SSD_MAX_HEADS = 8      # the ports axis: parallel head lanes

# analytical stage models: the attention stage prices as a gemma2-9b
# fleet share, the SSD stage as a mamba2-780m share, both on the
# train_4k shape cell (the fleet allocation problem of benchmarks/)
_FLEET_STAGES = {
    "flash_attention": ("gemma2-9b", 0),
    "ssd_scan": ("mamba2-780m", 0),
}


def default_measurement_path(tile: int = 0) -> str:
    """One recording file for the fleet kernels (no tile axis: the
    kernel geometry is fixed, so everything keys under tile 0)."""
    return os.path.join(_REPO_ROOT, "artifacts", "measurements",
                        "fleet_pallas.json")


# ----------------------------------------------------------------------
# system model + knob spaces
# ----------------------------------------------------------------------
def fleet_tmg(frames_in_flight: int = 2) -> TMG:
    """Single-buffer two-stage pipeline: adjacent stages serialize, so
    the TMG's one-token cycles certify them mutually exclusive and the
    PLM planner may pack both stages onto one shared VMEM pool."""
    return pipeline_tmg(["flash_attention", "ssd_scan"], buffers=1,
                        frames_in_flight=frames_in_flight)


def fleet_knob_spaces() -> Dict[str, KnobSpace]:
    """One knob space for both stages, honest for both backends: ports
    up to 4 (fleet shares / parallel grid lanes), unrolls up to 8
    (microbatch ladder / sequential block depth)."""
    return {n: KnobSpace(clock_ns=1.0, max_ports=4, max_unrolls=8)
            for n in _FLEET_STAGES}


def fleet_xla_tool() -> XLATool:
    """The analytical fleet oracle (roofline prices, HBM-byte areas)."""
    return XLATool({name: (get_config(cfg), SHAPES[shape])
                    for name, (cfg, shape) in _FLEET_STAGES.items()})


# ----------------------------------------------------------------------
# measured kernel specs
# ----------------------------------------------------------------------
def _flash_block_kv(unrolls: int) -> int:
    return 16 * unrolls


def flash_vmem_bytes(H: int, W: int, *, ports: int, unrolls: int,
                     dtype_bytes: int = 4) -> int:
    """Per-grid-step VMEM: q/o/acc tiles of (Sq/ports, d), k/v tiles of
    (16*unrolls, d), plus the (m, l) softmax state rows."""
    bq = W // ports
    bkv = _flash_block_kv(unrolls)
    return dtype_bytes * (3 * bq * FLASH_D + 2 * bkv * FLASH_D + 2 * bq)


def flash_grid_steps(H: int, W: int, *, ports: int, unrolls: int) -> int:
    return FLASH_HEADS * ports * max(1, H // _flash_block_kv(unrolls))


def _ssd_chunk(unrolls: int) -> int:
    return 8 * unrolls


def ssd_vmem_bytes(H: int, W: int, *, ports: int, unrolls: int,
                   dtype_bytes: int = 4) -> int:
    """Per-head-lane VMEM per chunk step: x/y tiles (chunk, P), B/C
    tiles (chunk, N), the dt row, and the carried (P, N) state."""
    chunk = _ssd_chunk(unrolls)
    return dtype_bytes * (2 * chunk * SSD_P + 2 * chunk * SSD_N + chunk
                          + 2 * SSD_P * SSD_N)


def ssd_grid_steps(H: int, W: int, *, ports: int, unrolls: int) -> int:
    return ports * max(1, H // _ssd_chunk(unrolls))


def _fleet_inputs():
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 8)
    q = jax.random.normal(ks[0], (1, FLASH_S, FLASH_HEADS, FLASH_D))
    k = jax.random.normal(ks[1], (1, FLASH_S, 1, FLASH_D))
    v = jax.random.normal(ks[2], (1, FLASH_S, 1, FLASH_D))
    x = jax.random.normal(ks[3], (1, SSD_S, SSD_MAX_HEADS, SSD_P))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (1, SSD_S, SSD_MAX_HEADS)))
    A = -jnp.exp(jax.random.normal(ks[5], (SSD_MAX_HEADS,)) * 0.3)
    Bm = jax.random.normal(ks[6], (1, SSD_S, SSD_N)) * 0.3
    Cm = jax.random.normal(ks[7], (1, SSD_S, SSD_N)) * 0.3
    return q, k, v, x, dt, A, Bm, Cm


def fleet_kernel_specs(tile: int = 0) -> Dict[str, PallasKernelSpec]:
    """The two fleet stages as measured kernel specs (deterministic
    baked inputs; ``tile`` is accepted for the components-factory
    protocol but the fleet geometry is fixed)."""
    q, k, v, x, dt, A, Bm, Cm = _fleet_inputs()

    def build_flash(ports: int, unrolls: int, interpret: bool):
        def run():
            return mha(q, k, v, causal=True,
                       block_q=FLASH_S // ports,
                       block_kv=_flash_block_kv(unrolls),
                       use_pallas=True, interpret=interpret)
        return run

    def build_ssd(ports: int, unrolls: int, interpret: bool):
        def run():
            return ssd(x[:, :, :ports, :], dt[:, :, :ports], A[:ports],
                       Bm, Cm, chunk=_ssd_chunk(unrolls),
                       use_pallas=True, interpret=interpret)
        return run

    return {
        "flash_attention": PallasKernelSpec(
            name="flash_attention", shape=(FLASH_S, FLASH_S),
            build=build_flash, vmem_bytes=flash_vmem_bytes,
            grid_steps=flash_grid_steps, n_in=3, n_out=1),
        "ssd_scan": PallasKernelSpec(
            name="ssd_scan", shape=(SSD_S, SSD_MAX_HEADS),
            build=build_ssd, vmem_bytes=ssd_vmem_bytes,
            grid_steps=ssd_grid_steps, n_in=4, n_out=2),
    }


def fleet_parity_cases(tile: int = FLASH_S):
    """(name, knobbed_fn, oracle_fn, args) for the parity gate: the
    fleet kernels behind the same (ports, unrolls) calling convention
    the WAMI cases use.  ``tile`` scales the token count (smoke runs
    shrink it)."""
    S = max(32, tile)
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 8)
    q = jax.random.normal(ks[0], (1, S, FLASH_HEADS, FLASH_D))
    k = jax.random.normal(ks[1], (1, S, 1, FLASH_D))
    v = jax.random.normal(ks[2], (1, S, 1, FLASH_D))
    x = jax.random.normal(ks[3], (1, S, SSD_MAX_HEADS, SSD_P))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (1, S, SSD_MAX_HEADS)))
    A = -jnp.exp(jax.random.normal(ks[5], (SSD_MAX_HEADS,)) * 0.3)
    Bm = jax.random.normal(ks[6], (1, S, SSD_N)) * 0.3
    Cm = jax.random.normal(ks[7], (1, S, SSD_N)) * 0.3

    def mha_knobbed(q, k, v, *, ports, unrolls, use_pallas, interpret):
        return mha(q, k, v, causal=True, block_q=max(1, S // ports),
                   block_kv=_flash_block_kv(unrolls),
                   use_pallas=use_pallas, interpret=interpret)

    def mha_oracle(q, k, v):
        return mha_ref(q, k, v, causal=True)

    def ssd_knobbed(x, dt, A, Bm, Cm, *, ports, unrolls, use_pallas,
                    interpret):
        # parity output must be knob-independent: ports only replicates
        # head lanes in the measured spec, so the check runs all heads
        # and lets unrolls (the chunk length) exercise the kernel
        return ssd(x, dt, A, Bm, Cm, chunk=_ssd_chunk(unrolls),
                   use_pallas=use_pallas, interpret=interpret)

    return [
        ("flash_attention", mha_knobbed, mha_oracle, (q, k, v)),
        ("ssd_scan", ssd_knobbed, ssd_oracle, (x, dt, A, Bm, Cm)),
    ]


# ----------------------------------------------------------------------
# oracles + calibration
# ----------------------------------------------------------------------
def fleet_pallas_oracle(mode: str = "replay", *,
                        measurements: Optional[MeasurementSet] = None,
                        fallback=None, interpret: bool = True,
                        flush_every: int = 16, missing: str = "fallback",
                        timer=None, **kwargs) -> PallasOracle:
    """The measured fleet oracle.  Default: deterministic replay of the
    checked-in interpret-mode recording with the *calibrated* XLA tool
    as fallback — the calibrated-measured backend of ``get_app("fleet")``."""
    if measurements is None and mode in ("record", "replay"):
        measurements = open_recording(default_measurement_path(),
                                      mode=mode, tile=0,
                                      interpret=interpret,
                                      flush_every=flush_every)
    if fallback is None:
        if mode == "replay" and missing == "fallback":
            fallback = fleet_calibrated_tool()
        else:
            fallback = fleet_xla_tool()
    return PallasOracle(fleet_kernel_specs(), mode=mode,
                        measurements=measurements,
                        components_factory=fleet_kernel_specs,
                        fallback=fallback, interpret=interpret,
                        missing=missing if mode == "replay" else "error",
                        record_hint="re-record with `python benchmarks/"
                                    "fleet_dse.py --record`",
                        timer=timer, **kwargs)


def fleet_unit_system(store: Optional[MeasurementStore] = None
                      ) -> UnitSystem:
    """Exchange rates fitted from the fleet recording: per-stage latency
    scales (measured wall / roofline model) and one global HBM-bytes ->
    VMEM-bytes area rate — the :mod:`repro.core.calibrate` fit applied
    to the XLA tool."""
    store = store or MeasurementStore.load(default_measurement_path())
    return fit_unit_system(store, fleet_kernel_specs(), fleet_xla_tool())


def fleet_calibrated_tool(store: Optional[MeasurementStore] = None):
    """The calibrated-measured analytical fallback: the XLA roofline
    re-scaled onto the measured latency axis and VMEM-byte cost unit."""
    return fleet_unit_system(store).calibrated(fleet_xla_tool())


def fleet_session(delta: float = 0.3, *, backend: str = "analytical",
                  workers: int = 1, share_plm: bool = False,
                  **kwargs) -> ExplorationSession:
    """``build_session("fleet", backend)`` with the fleet defaults."""
    tool = None
    if backend == "pallas":
        tool = fleet_pallas_oracle("replay")
    return build_session("fleet", backend, tool=tool, delta=delta,
                         workers=workers, share_plm=share_plm, **kwargs)


# ----------------------------------------------------------------------
# registration: `get_app("fleet")` resolves to this record
# ----------------------------------------------------------------------
register_app(App(
    name="fleet",
    description="hybrid attention + SSD serving pipeline: flash_attention "
                "-> ssd_scan, priced as fleet shares (XLA roofline) or "
                "measured Pallas kernels",
    tmg=fleet_tmg,
    knob_spaces=lambda **_kw: fleet_knob_spaces(),
    analytical=fleet_xla_tool,
    fixed={},
    delta=0.3,
    kernel_specs=fleet_kernel_specs,
    native_tile=0,
    measurement_path=default_measurement_path,
    recorded_tiles=(0,),
    default_tiles=(0,),
    calibrated_fallback=fleet_calibrated_tool,
    record_hint="re-record with `python benchmarks/fleet_dse.py --record`",
    plm_planner=lambda: PLMPlanner(fleet_tmg()),
    parity_cases=fleet_parity_cases,
))
