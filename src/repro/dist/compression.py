"""Blockwise int8 quantization + error-feedback gradient compression.

Quantization is absmax-per-block (the bound the tests assert:
|x - dequant(quant(x))| <= absmax/127 per block).  Error feedback keeps
the quantization residue and folds it into the next step's gradient, so
the long-run gradient sum is preserved (EF-SGD argument); the train step
applies it to the gradient tree right before the (simulated) all-reduce.
"""

from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_blockwise", "dequantize_blockwise", "ef_compress",
           "ef_compress_tree"]


def quantize_blockwise(x: jnp.ndarray, block: int = 256, *,
                       bits: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize to int8 with one absmax scale per ``block`` elements.

    Returns ``(q, scales)`` with ``q`` shaped (n_blocks, block) — padded
    with zeros past the original size — and ``scales`` shaped (n_blocks,).
    """
    qmax = (1 << (bits - 1)) - 1
    flat = x.reshape(-1)
    n = flat.size
    n_blocks = max(1, -(-n // block))
    pad = n_blocks * block - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(n_blocks, block)
    absmax = jnp.max(jnp.abs(blocks), axis=1)
    scales = absmax / qmax
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -qmax, qmax)
    return q.astype(jnp.int8), scales.astype(jnp.float32)


def dequantize_blockwise(q: jnp.ndarray, scales: jnp.ndarray,
                         shape: Tuple[int, ...]) -> jnp.ndarray:
    y = (q.astype(jnp.float32) * scales[:, None]).reshape(-1)
    return y[: math.prod(shape) if shape else 1].reshape(shape)


def ef_compress(g: jnp.ndarray, err: Optional[jnp.ndarray] = None, *,
                bits: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compress ``g`` (+ carried-in error) and return (g_hat, new error).

    Invariant: g_hat + new_error == g + carried_error (up to float eps),
    which is what makes the long-run gradient sum exact.
    """
    target = g if err is None else g + err
    q, s = quantize_blockwise(target, bits=bits)
    g_hat = dequantize_blockwise(q, s, target.shape).astype(g.dtype)
    return g_hat, (target - g_hat).astype(g.dtype)


def ef_compress_tree(tree: Any, err_tree: Optional[Any] = None, *,
                     bits: int = 8) -> Tuple[Any, Any]:
    """``ef_compress`` over a gradient pytree; returns (g_hat, errors)."""
    leaves, treedef = jax.tree.flatten(tree)
    errs = (jax.tree.leaves(err_tree) if err_tree is not None
            else [None] * len(leaves))
    pairs = [ef_compress(g, e, bits=bits) for g, e in zip(leaves, errs)]
    return (jax.tree.unflatten(treedef, [p[0] for p in pairs]),
            jax.tree.unflatten(treedef, [p[1] for p in pairs]))
