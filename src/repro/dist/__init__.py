"""Distribution substrate: sharding rules + gradient compression.

``sharding`` resolves path-pattern rules to ``NamedSharding``s (and
provides the in-model ``constrain*`` helpers, which no-op outside a
``mesh_context``); ``compression`` implements blockwise int8
quantization with error feedback for gradient all-reduce.
"""

from .compression import (dequantize_blockwise, ef_compress,
                          ef_compress_tree, quantize_blockwise)
from .sharding import (ShardingRules, batch_spec, cache_spec, constrain,
                       constrain_attn_qkv, constrain_residual, lm_rules,
                       mesh_context, residual_sharding, tree_paths,
                       zero1_spec)

__all__ = [
    "quantize_blockwise", "dequantize_blockwise", "ef_compress",
    "ef_compress_tree",
    "ShardingRules", "lm_rules", "tree_paths", "mesh_context",
    "residual_sharding", "constrain", "constrain_residual",
    "constrain_attn_qkv", "batch_spec", "cache_spec", "zero1_spec",
]
