"""Sharding-rule resolution and in-model sharding constraints.

Rules are (path-substring, logical-axes) pairs resolved against a mesh:

  * axis names absent from the mesh resolve to ``None`` (the same rule
    set drives a 1-device CPU run and the 512-chip production mesh);
  * a dimension whose size does not divide the mesh axis resolves to
    ``None`` (divisibility guard — reduced test models never trip the
    compiler);
  * rules are written for the weight's own dims; layer-stacked arrays
    (scan-over-layers layouts) are LEFT-padded with ``None``.

The ``constrain*`` helpers used inside model code are no-ops unless a
``mesh_context`` is active, so the same model code runs un-jitted on one
device and sharded under pjit.
"""

from __future__ import annotations

import contextlib
import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..utils import keystr_path

__all__ = [
    "tree_paths", "ShardingRules", "lm_rules", "mesh_context",
    "residual_sharding", "constrain", "constrain_residual",
    "constrain_attn_qkv", "batch_spec", "cache_spec", "zero1_spec",
]

Axis = Union[None, str, Tuple[str, ...]]

# stacks, innermost last (plain lists: jit traces run single-threaded)
_MESH_STACK: List[Mesh] = []
_RESIDUAL_STACK: List[Tuple[Axis, ...]] = [("data", None, None)]


def tree_paths(tree: Any) -> Any:
    """Same-structure tree whose leaves are 'a/b/0'-style path strings."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [keystr_path(kp) for kp, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, paths)


def _axis_names(ax: Axis) -> Tuple[str, ...]:
    if ax is None:
        return ()
    if isinstance(ax, tuple):
        return ax
    return (ax,)


def _resolve(axes: Sequence[Axis], mesh: Mesh,
             shape: Optional[Sequence[int]] = None) -> P:
    """Resolve logical axes to a PartitionSpec valid on ``mesh``."""
    out: List[Axis] = []
    for i, ax in enumerate(axes):
        names = tuple(n for n in _axis_names(ax) if n in mesh.shape)
        if not names:
            out.append(None)
            continue
        size = math.prod(mesh.shape[n] for n in names)
        if shape is not None and i < len(shape) and shape[i] % size != 0:
            out.append(None)
            continue
        out.append(names if len(names) > 1 else names[0])
    return P(*out)


def _fit(axes: Sequence[Axis], ndim: int) -> Tuple[Axis, ...]:
    """Left-pad (layer-stacked arrays) or left-trim rule axes to ndim."""
    axes = tuple(axes)
    if len(axes) < ndim:
        return (None,) * (ndim - len(axes)) + axes
    if len(axes) > ndim:
        return axes[len(axes) - ndim:]
    return axes


@dataclass(frozen=True)
class ShardingRules:
    """Ordered (path-substring, axes) rules; first match wins."""

    rules: Tuple[Tuple[str, Tuple[Axis, ...]], ...]

    def axes_for(self, path: str, ndim: int) -> Tuple[Axis, ...]:
        for pattern, axes in self.rules:
            if pattern in path:
                return _fit(axes, ndim)
        return (None,) * ndim

    def spec(self, path: str, ndim: int, mesh: Mesh,
             shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(mesh,
                             _resolve(self.axes_for(path, ndim), mesh, shape))

    def tree(self, params: Any, mesh: Mesh) -> Any:
        paths = tree_paths(params)
        return jax.tree.map(
            lambda leaf, path: self.spec(path, len(leaf.shape), mesh,
                                         tuple(leaf.shape)),
            params, paths)


def lm_rules(family: str, *, two_d_experts: bool = False) -> ShardingRules:
    """Megatron-style tensor-parallel rules for the model zoo.

    Experts shard on 'model'; ``two_d_experts`` additionally shards the
    expert FFN dim on 'data' (2D expert sharding for >200B MoE).
    """
    rules: List[Tuple[str, Tuple[Axis, ...]]] = [
        ("embed", ("model", None)),
        ("moe/router", (None, None)),
        ("moe/w_down", ("model", "data", None) if two_d_experts
         else ("model", None, None)),
        ("moe/w_gate", ("model", None, "data") if two_d_experts
         else ("model", None, None)),
        ("moe/w_up", ("model", None, "data") if two_d_experts
         else ("model", None, None)),
        ("attn/wq", (None, "model")),
        ("attn/wk", (None, "model")),
        ("attn/wv", (None, "model")),
        ("attn/wo", ("model", None)),
        ("mlp/w_up", (None, "model")),
        ("mlp/w_gate", (None, "model")),
        ("mlp/w_down", ("model", None)),
        ("ssm/in_proj", (None, "model")),
        ("ssm/out_proj", ("model", None)),
        ("in_proj", (None, "model")),
        ("out_proj", ("model", None)),
    ]
    return ShardingRules(rules=tuple(rules))


# ----------------------------------------------------------------------
# Contexts + in-model constraints
# ----------------------------------------------------------------------
@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    """Activate ``mesh`` for the ``constrain*`` helpers (and for named
    specs inside jit, via the Mesh context manager)."""
    _MESH_STACK.append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _MESH_STACK.pop()


@contextlib.contextmanager
def residual_sharding(axes: Tuple[Axis, ...]):
    """Override the residual-activation spec (e.g. ('data', 'model',
    None) for sequence parallelism) within the context."""
    _RESIDUAL_STACK.append(tuple(axes))
    try:
        yield
    finally:
        _RESIDUAL_STACK.pop()


def _active_mesh() -> Optional[Mesh]:
    return _MESH_STACK[-1] if _MESH_STACK else None


def constrain(x, axes: Sequence[Axis]):
    """with_sharding_constraint against the active mesh; identity when
    no mesh_context is active (single-device runs)."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    spec = _resolve(_fit(axes, x.ndim), mesh, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_residual(x):
    """(B, S, D) residual stream: data-parallel batch (+ optional
    sequence parallelism from ``residual_sharding``)."""
    return constrain(x, _RESIDUAL_STACK[-1])


def constrain_attn_qkv(q, k, v):
    """(B, S, H, hd) attention activations: heads on 'model'."""
    axes = (("pod", "data"), None, "model", None)
    return (constrain(q, axes), constrain(k, axes), constrain(v, axes))


# ----------------------------------------------------------------------
# Input/optimizer shardings (launch-time)
# ----------------------------------------------------------------------
def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(bspecs: Any, mesh: Mesh) -> Any:
    """Shard every batch leaf's leading dim over the data axes."""
    axes = _data_axes(mesh)

    def leaf(spec):
        if not axes or not spec.shape:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, _resolve(
            (axes,) + (None,) * (len(spec.shape) - 1), mesh,
            tuple(spec.shape)))

    return jax.tree.map(leaf, bspecs)


def cache_spec(cache_specs: Any, mesh: Mesh, *,
               seq_shard: bool = False) -> Any:
    """KV/state-cache shardings: batch over data axes; for batch-1
    decode (``seq_shard``) the sequence dim shards over 'model'."""
    axes = _data_axes(mesh)

    def leaf(spec):
        shape = tuple(spec.shape)
        if not shape:
            return NamedSharding(mesh, P())
        dims: List[Axis] = [None] * len(shape)
        if seq_shard and len(shape) >= 2:
            dims[1] = "model"
        elif axes:
            dims[0] = axes
        return NamedSharding(mesh, _resolve(tuple(dims), mesh, shape))

    return jax.tree.map(leaf, cache_specs)


def zero1_spec(param_sh: NamedSharding, shape: Tuple[int, ...],
               mesh: Mesh) -> NamedSharding:
    """ZeRO-1 optimizer-moment sharding: keep the param's spec and
    additionally shard the first still-replicated, divisible dim over
    the data axes."""
    axes = _data_axes(mesh)
    if not axes or not shape:
        return param_sh
    size = math.prod(mesh.shape[a] for a in axes)
    dims = list(_fit(tuple(param_sh.spec), len(shape)))
    for i, (ax, dim) in enumerate(zip(dims, shape)):
        if ax is None and dim % size == 0:
            dims[i] = axes if len(axes) > 1 else axes[0]
            break
    return NamedSharding(mesh, P(*dims))
