"""Serving engine: batched prefill + jitted decode loop + microbatcher.

``generate`` is the jit-compiled greedy/temperature sampler (prefill then
``lax.scan`` of decode steps).  ``ServeEngine`` adds the host-side layer
a deployment needs: fixed-shape request slots (padded batching), simple
continuous admission between decode bursts, and per-request stop/length
accounting.  Both operate purely through the model API (prefill /
decode_step), so every zoo family serves through the same engine.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

__all__ = ["generate", "ServeEngine", "Request"]


def make_generate(model, *, max_new: int, temperature: float = 0.0):
    """Build a jitted generate(params, batch, key) -> (B, max_new) fn."""

    @jax.jit
    def _generate(params, batch, key):
        B, S = batch["tokens"].shape
        logits, cache = model.prefill(params, batch, max_len=S + max_new)

        def sample(logits, key):
            if temperature <= 0.0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            g = jax.random.gumbel(key, logits.shape, jnp.float32)
            return jnp.argmax(logits / temperature + g, -1).astype(jnp.int32)

        k0, key = jax.random.split(key)
        tok0 = sample(logits, k0)

        def step(carry, _):
            tok, cache, key = carry
            key, sub = jax.random.split(key)
            logits, cache = model.decode_step(params, tok[:, None], cache)
            nxt = sample(logits, sub)
            return (nxt, cache, key), nxt

        (_, _, _), toks = lax.scan(step, (tok0, cache, key), None,
                                   length=max_new - 1)
        return jnp.concatenate([tok0[:, None], toks.T], axis=1)

    return _generate


def generate(model, params, batch, *, max_new: int, temperature: float = 0.0,
             key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    return make_generate(model, max_new=max_new,
                         temperature=temperature)(params, batch, key)


# ----------------------------------------------------------------------
# Host-side batched serving
# ----------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    prompt: np.ndarray                     # (S,) int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Padded-slot batched serving over the model API.

    Admissions happen between bursts: pending requests are padded to the
    slot shape (fixed compile footprint), prefilled as one batch, then
    decoded in bursts of ``burst`` steps.  Per-request completion is
    tracked host-side; finished slots are refilled from the queue.
    """

    def __init__(self, model, params, *, slots: int = 8, prompt_len: int = 64,
                 max_new: int = 32, temperature: float = 0.0):
        self.model = model
        self.params = params
        self.slots = slots
        self.prompt_len = prompt_len
        self.max_new = max_new
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * slots
        self._gen = make_generate(model, max_new=max_new,
                                  temperature=temperature)
        self._key = jax.random.PRNGKey(0)

    def submit(self, rid: int, prompt: np.ndarray, max_new: Optional[int] = None):
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new or self.max_new))

    def _pad(self, p: np.ndarray) -> np.ndarray:
        if len(p) >= self.prompt_len:
            return p[-self.prompt_len:]
        return np.pad(p, (self.prompt_len - len(p), 0))

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue; returns rid -> generated tokens."""
        results: Dict[int, List[int]] = {}
        while self.queue:
            burst = self.queue[: self.slots]
            self.queue = self.queue[self.slots:]
            prompts = np.stack([self._pad(r.prompt) for r in burst])
            if len(burst) < self.slots:   # pad batch to slot count
                fill = np.zeros((self.slots - len(burst), self.prompt_len),
                                np.int32)
                prompts = np.concatenate([prompts, fill])
            self._key, sub = jax.random.split(self._key)
            toks = np.asarray(self._gen(self.params,
                                        {"tokens": jnp.asarray(prompts)}, sub))
            for i, r in enumerate(burst):
                r.out = toks[i, : r.max_new].tolist()
                r.done = True
                results[r.rid] = r.out
        return results
