"""Serving: jitted generation + host-side batched engine, and the
concurrent multi-tenant DSE service frontend."""

from .dse_service import Busy, DSEService, QueryHandle
from .engine import Request, ServeEngine, generate, make_generate

__all__ = ["generate", "make_generate", "ServeEngine", "Request",
           "DSEService", "QueryHandle", "Busy"]
