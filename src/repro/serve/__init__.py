"""Serving: jitted generation + host-side batched engine."""

from .engine import Request, ServeEngine, generate, make_generate

__all__ = ["generate", "make_generate", "ServeEngine", "Request"]
