"""DSE-as-a-service: many concurrent tenants, shared oracles.

COSMOS's headline result is oracle frugality *within one run*; this
module extends the discipline *across* runs.  A :class:`DSEService`
accepts many concurrent :class:`~repro.core.session.DSEQuery`\\ s —
different apps, budgets, tiles, backends, all resolved through
:mod:`repro.core.registry` — and multiplexes them onto shared oracles,
in the shape of CHARM's async task queues feeding duplicated
accelerators:

  * **submission queue with backpressure** — at most ``max_pending``
    queries may sit queued; further submitters block (optionally with a
    timeout) or get a :class:`Busy` result back, never an unbounded
    queue;
  * **request coalescing** — queries that resolve to the same oracle
    pool (same ``(app, backend, share_plm, tiles)``) share one
    :class:`~repro.core.oracle.SharedOracle`: identical ``(component,
    knob, tile)`` points from different tenants join one in-flight tool
    call, and distinct points pending together drain into single
    ``evaluate_batch`` calls;
  * **cross-tenant cache** — each pool carries a
    :class:`~repro.core.oracle.PersistentOracleCache` (optionally
    LRU-bounded via ``cache_entries``, optionally durable via
    ``cache_root``) so a later tenant never re-pays a point an earlier
    tenant already bought;
  * **per-tenant ledger attribution** — every query runs under its own
    :class:`~repro.core.oracle.OracleLedger`, so each tenant's
    invocation counts (and therefore its front) are byte-identical to
    an isolated run, while the pool's shared ledger records the real
    (strictly smaller, under overlap) tool traffic;
  * **async completion** — :meth:`DSEService.submit` returns a
    :class:`QueryHandle` immediately; tenants ``poll()`` or block on
    ``result()``/``wait()``.

Failure isolation: a tenant whose oracle raises fails *its own*
handle — the exception is re-raised from ``result()`` — and nothing
poisons the shared state: errors are never cached, and every other
tenant's front is unaffected (tests/test_dse_service.py seeds exactly
this).  See docs/service.md for the query lifecycle.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple, Union

from ..core.obs import NULL_TRACER, MetricsRegistry
from ..core.oracle import OracleLedger, PersistentOracleCache, SharedOracle
from ..core.pricing import BatchPricer
from ..core.registry import build_query_session, build_tool, get_app, get_backend
from ..core.session import CosmosResult, DSEQuery

__all__ = ["Busy", "QueryHandle", "DSEService"]


@dataclass(frozen=True)
class Busy:
    """The backpressure answer: the queue was full (and stayed full for
    the whole ``timeout``, if one was given).  Resubmit later — nothing
    was enqueued."""

    reason: str


class QueryHandle:
    """One submitted query's future: poll it or await it.

    ``status`` moves ``queued -> running -> done | failed``.  After
    completion, ``ledger`` carries the tenant's own
    :class:`~repro.core.oracle.OracleLedger` — the per-tenant Fig. 11
    attribution (identical to an isolated run of the same query).
    """

    def __init__(self, qid: int, query: DSEQuery):
        self.qid = qid
        self.query = query
        self.status = "queued"
        self.ledger: Optional[OracleLedger] = None
        self.wall_s: float = 0.0
        self._result: Optional[CosmosResult] = None
        self._error: Optional[BaseException] = None
        self._event = threading.Event()
        # lifecycle spans, installed by DSEService.submit: the root
        # ``service.query`` span (submit -> completion) and its
        # ``service.queued`` child (submit -> dispatch)
        self._span = None
        self._queued_span = None
        self._submit_t = 0.0

    # -- poll ----------------------------------------------------------
    def done(self) -> bool:
        return self._event.is_set()

    def poll(self) -> str:
        return self.status

    # -- await ---------------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def result(self, timeout: Optional[float] = None) -> CosmosResult:
        if not self._event.wait(timeout):
            raise TimeoutError(f"query {self.qid} ({self.query.app}/"
                               f"{self.query.backend}) still "
                               f"{self.status} after {timeout}s")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        if not self._event.wait(timeout):
            raise TimeoutError(f"query {self.qid} still {self.status}")
        return self._error

    def invocations(self) -> Dict[str, int]:
        """The tenant's attributed per-component invocation counts."""
        return dict(self.ledger.invocations) if self.ledger else {}

    def outcome_counts(self) -> Dict[str, int]:
        """The tenant ledger's per-point outcome partition
        (``fresh | cache_hit | inflight_join | replay``)."""
        return self.ledger.outcome_counts() if self.ledger else {}

    # -- service side --------------------------------------------------
    def _finish(self, result: Optional[CosmosResult],
                error: Optional[BaseException]) -> None:
        self._result = result
        self._error = error
        self.status = "done" if error is None else "failed"
        self._event.set()


def _pool_slug(key: Tuple[str, str, bool, Tuple[int, ...]]) -> str:
    app, backend, share_plm, tiles = key
    slug = f"{app}-{backend}"
    if share_plm:
        slug += "-share_plm"
    if tiles:
        slug += "-tiles" + "_".join(str(t) for t in tiles)
    return slug


@dataclass
class _Pool:
    """One shared oracle + its cache, keyed by ``DSEQuery.pool_key``."""

    slug: str
    oracle: SharedOracle
    cache: PersistentOracleCache
    tenants: int = 0            # queries that ran through this pool
    # per-delta Pareto-front cardinality of the most recent completed
    # query (``{"delta=0.25": 7, ...}``) — the SoC composer and
    # operators read front sizes from ``stats()`` without re-running
    front_sizes: Dict[str, int] = field(default_factory=dict)


class DSEService:
    """The concurrent multi-tenant DSE frontend.

    ``workers`` service threads drain the bounded submission queue and
    run one :class:`~repro.core.session.ExplorationSession` per query;
    sessions whose queries resolve to the same oracle pool share a
    :class:`~repro.core.oracle.SharedOracle` (coalescing + cross-tenant
    cache).  ``cache_entries`` LRU-bounds each pool's cache;
    ``cache_root`` makes the caches durable (one subdirectory per
    pool); ``verify_plans`` turns on the strict plan post-pass for
    every tenant session.

    Use as a context manager, or call :meth:`close` — queued and
    running queries complete first (``close(drain=False)`` abandons the
    queue: still-queued handles fail with :class:`ServiceClosed`).
    """

    def __init__(self, *, max_pending: int = 8, workers: int = 2,
                 cache_entries: Optional[int] = None,
                 cache_root: Optional[str] = None,
                 flush_every: int = 16,
                 verify_plans: bool = False,
                 tracer=None,
                 metrics: Optional[MetricsRegistry] = None):
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.max_pending = max_pending
        self.cache_entries = cache_entries
        self.cache_root = cache_root
        self.flush_every = flush_every
        self.verify_plans = verify_plans
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # one registry for the whole service: the query counters below,
        # queue-wait/latency histograms, per-pool shared-oracle and cache
        # counters, and per-tenant ledger outcome counters all land here;
        # ``stats()`` embeds its snapshot
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._submitted = self.metrics.counter("service.submitted")
        self._done = self.metrics.counter("service.done")
        self._failed = self.metrics.counter("service.failed")
        self._rejected = self.metrics.counter("service.rejected_busy")
        self._tenant_invocations = self.metrics.counter(
            "service.tenant_invocations")
        self._queued_g = self.metrics.gauge("service.queued")
        self._running_g = self.metrics.gauge("service.running")
        self._queue_wait_h = self.metrics.histogram("service.queue_wait_s")
        self._latency_h = self.metrics.histogram("service.latency_s")
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queue: Deque[QueryHandle] = deque()
        self._pools: Dict[Tuple[str, str, bool, Tuple[int, ...]], _Pool] = {}
        self._closed = False
        self._next_qid = 0
        self._running = 0
        self._workers = [threading.Thread(target=self._worker_loop,
                                          name=f"dse-service-{i}",
                                          daemon=True)
                         for i in range(max(1, workers))]
        for t in self._workers:
            t.start()

    # -- submission ----------------------------------------------------
    def submit(self, query: DSEQuery, *, block: bool = True,
               timeout: Optional[float] = None
               ) -> Union[QueryHandle, Busy]:
        """Enqueue one query; returns its :class:`QueryHandle`, or
        :class:`Busy` under backpressure.

        Unknown app/backend names raise the registry's listing errors
        here, synchronously — a bad query never occupies a queue slot.
        ``block=False`` returns :class:`Busy` immediately when the
        queue is full; ``block=True`` waits (at most ``timeout``
        seconds, forever when None) for a slot.
        """
        get_app(query.app)              # registry-style KeyError on typos
        get_backend(query.backend)
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            if self._closed:
                raise RuntimeError("DSEService is closed")
            while len(self._queue) >= self.max_pending:
                reason = (f"queue full ({self.max_pending} pending); "
                          f"resubmit later")
                if not block:
                    self._rejected.inc()
                    self.tracer.instant("service.rejected",
                                        tenant=query.tenant, app=query.app)
                    return Busy(reason)
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    self._rejected.inc()
                    return Busy(reason + f" (timed out after {timeout}s)")
                if not self._cv.wait(remaining):
                    self._rejected.inc()
                    return Busy(reason + f" (timed out after {timeout}s)")
                if self._closed:
                    raise RuntimeError("DSEService is closed")
            handle = QueryHandle(self._next_qid, query)
            self._next_qid += 1
            self._submitted.inc()
            # the query's root span opens at submit and is finished by
            # the worker at completion; its first child covers the
            # queue-wait (finished at dispatch)
            handle._span = self.tracer.begin(
                "service.query", qid=handle.qid, tenant=query.tenant,
                app=query.app, backend=query.backend)
            handle._queued_span = self.tracer.begin(
                "service.queued", parent=handle._span, qid=handle.qid)
            handle._submit_t = time.monotonic()
            self._queue.append(handle)
            self._queued_g.set(len(self._queue))
            self._cv.notify_all()
        return handle

    def submit_all(self, queries: List[DSEQuery],
                   timeout: Optional[float] = None) -> List[QueryHandle]:
        """Blocking convenience: submit every query (waiting out
        backpressure) and return the handles in order."""
        out = []
        for q in queries:
            h = self.submit(q, block=True, timeout=timeout)
            if isinstance(h, Busy):
                raise TimeoutError(f"submit_all stalled: {h.reason}")
            out.append(h)
        return out

    # -- the oracle pools ----------------------------------------------
    def _pool(self, query: DSEQuery) -> _Pool:
        key = query.pool_key
        with self._lock:
            pool = self._pools.get(key)
            if pool is None:
                slug = _pool_slug(key)
                root = (None if self.cache_root is None else
                        f"{self.cache_root}/{slug}")
                cache = PersistentOracleCache(
                    root, flush_every=self.flush_every,
                    max_entries=self.cache_entries,
                    metrics=self.metrics, name=slug)
                tool = build_tool(query.app, query.backend,
                                  share_plm=query.share_plm,
                                  tiles=query.tiles)
                # pool-level whole-grid pricing: analytical tools answer
                # every tenant's scalar request from one shared, memoized
                # grid per (component, tile) — bit-exact, so coalescing
                # and per-tenant attribution are unchanged; measured
                # tools pass through wrap() untouched
                tool = BatchPricer.wrap(tool)
                pool = _Pool(slug=slug, cache=cache,
                             oracle=SharedOracle(tool, cache=cache,
                                                 name=slug,
                                                 tracer=self.tracer,
                                                 metrics=self.metrics))
                self._pools[key] = pool
            pool.tenants += 1
            return pool

    # -- workers -------------------------------------------------------
    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if not self._queue:
                    return                   # closed and drained
                handle = self._queue.popleft()
                self._running += 1
                self._queued_g.set(len(self._queue))
                self._running_g.set(self._running)
                self._cv.notify_all()        # a queue slot freed up
            try:
                self._run(handle)
            finally:
                with self._cv:
                    self._running -= 1
                    self._running_g.set(self._running)
                    self._cv.notify_all()

    def _run(self, handle: QueryHandle) -> None:
        handle.status = "running"
        handle._queued_span.finish()         # queue-wait ends at dispatch
        self._queue_wait_h.observe(time.monotonic() - handle._submit_t)
        t0 = time.monotonic()
        tenant = handle.query.tenant or f"q{handle.qid}"
        try:
            pool = self._pool(handle.query)
            ledger = OracleLedger(pool.oracle,
                                  workers=handle.query.workers,
                                  tracer=self.tracer,
                                  metrics=self.metrics, name=tenant)
            handle.ledger = ledger
            # a context-managed child of the query's root span: the
            # session (which adopts the ledger's tracer) nests its phase
            # spans under it via this worker thread's span stack
            with self.tracer.span("service.run", parent=handle._span,
                                  qid=handle.qid, tenant=tenant,
                                  pool=pool.slug):
                session = build_query_session(
                    handle.query, ledger=ledger,
                    verify_plans=self.verify_plans)
                result = session.run()
            with self._lock:
                pool.front_sizes[f"delta={session.delta:g}"] = \
                    len(result.pareto())
        except BaseException as exc:  # noqa: BLE001 — isolated per tenant
            handle.wall_s = time.monotonic() - t0
            self._latency_h.observe(handle.wall_s)
            self._failed.inc()
            handle._span.set("status", "failed")
            handle._span.finish(exc)
            handle._finish(None, exc)
            return
        handle.wall_s = time.monotonic() - t0
        self._latency_h.observe(handle.wall_s)
        self._done.inc()
        self._tenant_invocations.inc(ledger.total())
        handle._span.set("invocations", ledger.total())
        handle._span.finish()
        handle._finish(result, None)

    # -- introspection -------------------------------------------------
    def shared_invocations(self) -> int:
        """Real tool calls across every pool — the service-wide shared
        ledger total.  Under any cross-tenant overlap this is strictly
        below the sum of the per-tenant attributions."""
        with self._lock:
            pools = list(self._pools.values())
        return sum(p.oracle.total() for p in pools)

    def stats(self) -> Dict[str, Any]:
        """Service-wide picture: the historical query/pool summary plus
        ``metrics`` — the full registry snapshot (counters, gauges,
        queue-wait/latency histograms, per-pool cache and shared-oracle
        counters, per-tenant outcome partitions).  See
        docs/observability.md for the field inventory."""
        with self._lock:
            pools = dict(self._pools)
            front_sizes = {p.slug: dict(sorted(p.front_sizes.items()))
                           for p in pools.values()}
            out: Dict[str, Any] = {
                "queries": {"submitted": self._submitted.value,
                            "done": self._done.value,
                            "failed": self._failed.value,
                            "rejected_busy": self._rejected.value,
                            "queued": len(self._queue),
                            "running": self._running},
                "tenant_invocations": self._tenant_invocations.value,
            }
        out["pools"] = {p.slug: dict(p.oracle.stats(), tenants=p.tenants,
                                     front_sizes=front_sizes[p.slug])
                        for p in pools.values()}
        out["shared_invocations"] = sum(
            p.oracle.total() for p in pools.values())
        out["metrics"] = self.metrics.snapshot()
        return out

    # -- lifecycle -----------------------------------------------------
    def close(self, *, drain: bool = True) -> None:
        """Stop the service.  ``drain=True`` (default) lets queued and
        running queries finish; ``drain=False`` fails still-queued
        handles immediately (running ones still finish)."""
        with self._cv:
            if self._closed:
                return
            abandoned: List[QueryHandle] = []
            if not drain:
                abandoned = list(self._queue)
                self._queue.clear()
            self._closed = True
            self._cv.notify_all()
        for h in abandoned:
            err = RuntimeError("DSEService closed before this query ran")
            h._queued_span.finish(err)
            h._span.finish(err)
            h._finish(None, err)
        for t in self._workers:
            t.join()
        for pool in self._pools.values():
            pool.oracle.close()

    def __enter__(self) -> "DSEService":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
