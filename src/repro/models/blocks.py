"""Transformer building blocks shared by the model zoo.

Pure-functional JAX: every block is an ``init(key, cfg) -> params`` plus
an ``apply(params, x, ...) -> y`` pair operating on explicit pytrees, so
the whole model stays a pytree-in/pytree-out function compatible with
``jax.lax.scan`` over stacked layer parameters and with pjit sharding by
parameter path (see ``repro.dist.sharding``).

Covers every attention flavour in the assignment: GQA, RoPE and M-RoPE,
QKV bias, attention/logit soft-capping, sliding-window masks (with the
window as a *traced* per-layer scalar so gemma2's local/global
alternation lives inside one ``lax.scan``), and KV-cache decode.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from ..dist.sharding import constrain, constrain_attn_qkv

__all__ = [
    "init_norm", "apply_norm", "init_attention", "apply_attention",
    "init_mlp", "apply_mlp", "init_moe", "apply_moe",
    "rope", "mrope", "make_positions", "softcap",
    "attention_core", "Params",
]

Params = Dict[str, Any]

_INIT_STD = 0.02


def _dense_init(key, shape, dtype, std=_INIT_STD):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    if cfg.norm_kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.zeros((d,), dtype)}   # rmsnorm stores (scale - 1)


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(x.dtype)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(ms + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


# ----------------------------------------------------------------------
# Rotary embeddings (RoPE and M-RoPE)
# ----------------------------------------------------------------------

def _rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> Tuple:
    """positions (..., S) -> cos/sin (..., S, head_dim/2)."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Apply rotary embedding.  x: (B, S, H, hd); positions: (B, S)."""
    hd = x.shape[-1]
    cos, sin = _rope_angles(positions, hd, theta)       # (B, S, hd/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
          sections: Tuple[int, int, int] = (16, 24, 24)) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: three position streams (temporal, height,
    width) rotate disjoint head-dim sections.  positions3: (3, B, S);
    ``sections`` are half-dim section sizes (sum = head_dim/2)."""
    hd = x.shape[-1]
    half = hd // 2
    secs = list(sections)
    if sum(secs) != half:          # scale sections for reduced configs
        base = half // 3
        secs = [half - 2 * base, base, base]
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # pick which position stream drives each frequency index
    stream = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                              for i, s in enumerate(secs)])
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32).transpose(1, 2, 0),   # (B, S, 3)
        stream[None, None, :].repeat(positions3.shape[1], 0), axis=-1)
    ang = pos * freq                                          # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def make_positions(batch: int, seq: int, offset=0) -> jnp.ndarray:
    return jnp.arange(seq, dtype=jnp.int32)[None, :] + offset + jnp.zeros(
        (batch, 1), jnp.int32)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x


# ----------------------------------------------------------------------
# Attention
# ----------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.hd()
    H, K = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": _dense_init(ks[0], (d, H * hd), dtype),
        "wk": _dense_init(ks[1], (d, K * hd), dtype),
        "wv": _dense_init(ks[2], (d, K * hd), dtype),
        "wo": _dense_init(ks[3], (H * hd, d), dtype, std=_INIT_STD / math.sqrt(2 * max(1, cfg.n_layers))),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    return p


def _mask_bias(q_pos: jnp.ndarray, kv_pos: jnp.ndarray, window,
               causal: bool) -> jnp.ndarray:
    """Additive mask (B, 1, Sq, Skv) from positions.  ``window`` may be a
    traced scalar: 0 => global attention."""
    dist = q_pos[:, :, None] - kv_pos[:, None, :]         # (B, Sq, Skv)
    ok = jnp.ones_like(dist, dtype=bool)
    if causal:
        ok = ok & (dist >= 0)
    win = jnp.asarray(window)
    ok = ok & ((win <= 0) | (dist < win))
    return jnp.where(ok, 0.0, -1e30)[:, None, :, :]


def attention_core(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   q_pos: jnp.ndarray, kv_pos: jnp.ndarray, *,
                   causal: bool = True, window=0, attn_cap: float = 0.0,
                   kv_chunk: int = 0) -> jnp.ndarray:
    """Grouped-query attention core.

    q: (B, Sq, H, hd); k, v: (B, Skv, K, hd); H a multiple of K.
    ``kv_chunk`` > 0 switches to the online-softmax streaming form (exact,
    bounded memory — the pure-XLA analogue of flash attention; the Pallas
    kernel in ``repro.kernels.flash_attention`` is the TPU version).
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qf = (q * scale).astype(jnp.float32).reshape(B, Sq, K, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    if not kv_chunk or kv_chunk >= k.shape[1]:
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf)
        s = softcap(s, attn_cap)
        bias = _mask_bias(q_pos, kv_pos, window, causal)   # (B,1,Sq,Skv)
        s = s + bias[:, :, None, :, :]
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
        return o.reshape(B, Sq, H, hd).astype(q.dtype)

    # ---- streaming online-softmax over KV chunks -----------------------
    Skv = k.shape[1]
    n_chunks = (Skv + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - Skv
    kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kvp = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
    kc = kf.reshape(B, n_chunks, kv_chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = vf.reshape(B, n_chunks, kv_chunk, K, hd).transpose(1, 0, 2, 3, 4)
    pc = kvp.reshape(B, n_chunks, kv_chunk).transpose(1, 0, 2)

    m0 = jnp.full((B, K, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, hd), jnp.float32)

    def step(carry, chunk):
        m, l, acc = carry
        kck, vck, pck = chunk
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kck)
        s = softcap(s, attn_cap)
        bias = _mask_bias(q_pos, pck, window, causal)      # (B,1,Sq,c)
        s = s + bias[:, :, None, :, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked chunks (max = -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, vck)
        return (m_new, l, acc), None

    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]             # (B,K,G,Sq,hd)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return o.astype(q.dtype)


def apply_attention(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                    positions: jnp.ndarray, *,
                    kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    kv_positions: Optional[jnp.ndarray] = None,
                    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    cache_len=None,
                    causal: bool = True, window=0,
                    kv_chunk: int = 0,
                    ) -> Tuple[jnp.ndarray, Optional[Tuple]]:
    """Full attention block (projections + core + output).

    Modes:
      * self-attention over x (training / prefill): kv=None, cache=None;
      * cross-attention: kv = (k_pre, v_pre) precomputed encoder K/V;
      * cached decode: ``cache=(k_cache, v_cache)`` with ``cache_len``
        giving the number of valid positions; x is the new token(s).
    Returns (output, new_cache_or_None).
    """
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd()

    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, H, hd)

    if kv is None:
        k = x @ p["wk"]
        v = x @ p["wv"]
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = k.reshape(B, S, K, hd)
        v = v.reshape(B, S, K, hd)
        if cfg.mrope and positions.ndim == 3:
            q = mrope(q, positions, cfg.rope_theta)
            k = mrope(k, positions, cfg.rope_theta)
            pos2d = positions[0]
        elif cfg.rope_theta > 0 and cfg.family != "encdec":
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            pos2d = positions
        else:
            pos2d = positions if positions.ndim == 2 else positions[0]
    else:
        k, v = kv
        pos2d = positions if positions.ndim == 2 else positions[0]

    new_cache = None
    if cache is not None:
        k_cache, v_cache = cache
        Smax = k_cache.shape[1]
        # insert the new K/V at cache_len (dynamic update slice)
        start = jnp.asarray(cache_len, jnp.int32)
        k_cache = lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, start, 0, 0))
        v_cache = lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, start, 0, 0))
        new_cache = (k_cache, v_cache)
        k, v = k_cache, v_cache
        kv_pos = jnp.arange(Smax, dtype=jnp.int32)[None, :].repeat(B, 0)
        # positions beyond cache_len + S are invalid -> mask via huge pos
        valid = kv_pos < (start + S)
        kv_pos = jnp.where(valid, kv_pos, 2**30)
    elif kv_positions is not None:
        kv_pos = kv_positions
    else:
        kv_pos = pos2d

    q, k, v = constrain_attn_qkv(q, k, v)
    o = attention_core(q, k, v, pos2d, kv_pos, causal=causal, window=window,
                       attn_cap=cfg.attn_softcap, kv_chunk=kv_chunk)
    out = o.reshape(B, S, H * hd) @ p["wo"]
    return out, new_cache


# ----------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    out_std = _INIT_STD / math.sqrt(2 * max(1, cfg.n_layers))
    if cfg.mlp_kind == "silu_gated":
        return {"w_gate": _dense_init(ks[0], (d, f), dtype),
                "w_up": _dense_init(ks[1], (d, f), dtype),
                "w_down": _dense_init(ks[2], (f, d), dtype, std=out_std)}
    return {"w_up": _dense_init(ks[0], (d, f), dtype),
            "w_down": _dense_init(ks[1], (f, d), dtype, std=out_std)}


def apply_mlp(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.mlp_kind == "silu_gated":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    h = x @ p["w_up"]
    if cfg.mlp_kind == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return h @ p["w_down"]


# ----------------------------------------------------------------------
# Mixture of Experts
# ----------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.expert_ff()
    ks = jax.random.split(key, 5)
    out_std = _INIT_STD / math.sqrt(2 * max(1, cfg.n_layers))
    p: Params = {
        "router": _dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": _dense_init(ks[1], (E, d, f), dtype),
        "w_up": _dense_init(ks[2], (E, d, f), dtype),
        "w_down": _dense_init(ks[3], (E, f, d), dtype, std=out_std),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {"w_gate": _dense_init(k1, (d, fs), dtype),
                       "w_up": _dense_init(k2, (d, fs), dtype),
                       "w_down": _dense_init(k3, (fs, d), dtype, std=out_std)}
    return p


def apply_moe(p: Params, cfg: ModelConfig, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-choice top-k MoE with fixed expert capacity.

    Sort-free static-shape dispatch: each (token, k) slot computes its
    rank within its expert via argsort; slots past the capacity are
    dropped (scatter mode='drop').  Expert compute is a batched matmul
    (E, C, d) x (E, d, f), so EP sharding of the leading E axis is a pure
    pjit annotation.  Returns (y, aux_loss).
    """
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, int(math.ceil(T * k * cfg.capacity_factor / E)))
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = lax.top_k(probs, k)                          # (T, k)
    gate = (gate / jnp.sum(gate, axis=-1, keepdims=True)).astype(x.dtype)

    flat_e = eidx.reshape(-1)                                 # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_sorted = jnp.arange(T * k, dtype=jnp.int32) - start[sorted_e].astype(jnp.int32)
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted)

    token_of_slot = jnp.arange(T * k, dtype=jnp.int32) // k
    table = jnp.full((E, C), T, jnp.int32)                    # T = sentinel
    table = table.at[flat_e, pos].set(token_of_slot, mode="drop")

    x_ext = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = x_ext[table]                                          # (E, C, d)
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
         * jnp.einsum("ecd,edf->ecf", xe, p["w_up"]))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])            # (E, C, d)

    # combine: gather each slot's expert output; dropped slots -> 0
    ye_ext = jnp.concatenate(
        [ye, jnp.zeros((E, 1, d), ye.dtype)], axis=1)          # (E, C+1, d)
    safe_pos = jnp.minimum(pos, C)
    kept = (pos < C)[:, None].astype(ye.dtype)
    y_slot = ye_ext[flat_e, safe_pos] * kept                   # (T*k, d)
    y = jnp.sum(y_slot.reshape(T, k, d) * gate[..., None], axis=1)

    if cfg.n_shared_experts:
        sp = p["shared"]
        y = y + (jax.nn.silu(xt @ sp["w_gate"]) * (xt @ sp["w_up"])) @ sp["w_down"]

    # Switch-style load-balancing auxiliary loss
    density = jnp.mean(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=(0, 1))
    router_mean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * router_mean)
    return y.reshape(B, S, d), aux
