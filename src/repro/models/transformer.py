"""Decoder-only transformer LM (dense and MoE families).

Layer parameters are stacked along a leading L axis and the forward pass
is a single ``lax.scan`` over layers, so full-size configs (80L / 61L)
lower to one compiled layer body — essential for the 512-device dry-run.
Per-layer *structure* differences (gemma2's local/global alternation) are
expressed as per-layer scalar scan inputs (the sliding-window size), not
as python branches, keeping one code path.

Entry points (used by train/serve/launch):
  * ``init``         — parameter pytree
  * ``loss``         — next-token CE (+ MoE aux), seq-chunked for big vocabs
  * ``prefill``      — build KV caches, return last-position logits
  * ``decode_step``  — one token with KV caches
"""

from __future__ import annotations

import functools
import math
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from ..dist.sharding import constrain, constrain_residual
from ..train.remat import maybe_remat
from .blocks import (Params, _dense_init, apply_attention, apply_mlp,
                     apply_moe, apply_norm, init_attention, init_mlp,
                     init_moe, init_norm, make_positions, softcap)

__all__ = ["DecoderLM"]

_PREFILL_CHUNK_THRESHOLD = 16384   # switch attention to streaming form
_KV_CHUNK = 1024
_LOSS_VOCAB_THRESHOLD = 65536      # seq-chunk the CE loss above this vocab
_LOSS_CHUNK = 512


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


class DecoderLM:
    """Dense or MoE decoder LM defined by a ModelConfig."""

    def __init__(self, cfg: ModelConfig):
        if cfg.family not in ("dense", "moe"):
            raise ValueError(cfg.family)
        self.cfg = cfg

    # ------------------------------------------------------------------
    # Parameters
    # ------------------------------------------------------------------
    def _init_layer(self, key, moe: bool) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p: Params = {
            "ln1": init_norm(cfg, dt),
            "attn": init_attention(k1, cfg, dt),
            "ln2": init_norm(cfg, dt),
        }
        if cfg.post_norms:
            p["ln1_post"] = init_norm(cfg, dt)
            p["ln2_post"] = init_norm(cfg, dt)
        if moe:
            p["moe"] = init_moe(k2, cfg, dt)
        else:
            p["mlp"] = init_mlp(k3, cfg, dt)
        return p

    def init(self, key) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = jax.random.split(key, cfg.n_layers + 3)
        n_dense = cfg.first_dense_layers if cfg.n_experts else 0
        n_scan = cfg.n_layers - n_dense
        moe_scan = bool(cfg.n_experts)

        params: Params = {
            "embed": _dense_init(keys[0], (cfg.vocab, cfg.d_model), dt),
            "final_norm": init_norm(cfg, dt),
        }
        if not cfg.tie_embeddings:
            params["head"] = _dense_init(keys[1], (cfg.d_model, cfg.vocab), dt)
        if n_dense:
            params["dense_layers"] = jax.vmap(
                lambda k: self._init_layer(k, moe=False)
            )(jnp.stack(keys[2:2 + n_dense]))
        params["layers"] = jax.vmap(
            lambda k: self._init_layer(k, moe=moe_scan)
        )(jnp.stack(keys[2 + n_dense:2 + n_dense + n_scan]))
        return params

    # ------------------------------------------------------------------
    # Per-layer windows (gemma2 local/global alternation)
    # ------------------------------------------------------------------
    def _windows(self, n: int, offset: int = 0) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.local_global_alternate and cfg.sliding_window:
            idx = jnp.arange(offset, offset + n)
            return jnp.where(idx % 2 == 0, cfg.sliding_window, 0).astype(jnp.int32)
        if cfg.sliding_window:
            return jnp.full((n,), cfg.sliding_window, jnp.int32)
        return jnp.zeros((n,), jnp.int32)

    # ------------------------------------------------------------------
    # Layer body
    # ------------------------------------------------------------------
    def _block(self, lp: Params, x, positions, window, *, moe: bool,
               kv_chunk: int = 0, cache=None, cache_len=None):
        cfg = self.cfg
        h = apply_norm(lp["ln1"], x, cfg.norm_kind)
        attn_out, new_cache = apply_attention(
            lp["attn"], cfg, h, positions, cache=cache, cache_len=cache_len,
            causal=True, window=window, kv_chunk=kv_chunk)
        if cfg.post_norms:
            attn_out = apply_norm(lp["ln1_post"], attn_out, cfg.norm_kind)
        x = x + attn_out
        h = apply_norm(lp["ln2"], x, cfg.norm_kind)
        aux = jnp.zeros((), jnp.float32)
        if moe:
            mlp_out, aux = apply_moe(lp["moe"], cfg, h)
        else:
            mlp_out = apply_mlp(lp["mlp"], cfg, h)
        if cfg.post_norms:
            mlp_out = apply_norm(lp["ln2_post"], mlp_out, cfg.norm_kind)
        return x + mlp_out, aux, new_cache

    # ------------------------------------------------------------------
    # Forward over all layers
    # ------------------------------------------------------------------
    def _forward(self, params: Params, x, positions, *, kv_chunk: int = 0
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Sequence forward (no caches).  Returns (hidden, aux_loss)."""
        cfg = self.cfg
        n_dense = cfg.first_dense_layers if cfg.n_experts else 0
        if n_dense:
            dl = params["dense_layers"]
            wins = self._windows(n_dense)
            for i in range(n_dense):
                lp = jax.tree.map(lambda a: a[i], dl)
                x, _, _ = self._block(lp, x, positions, wins[i], moe=False,
                                      kv_chunk=kv_chunk)
        moe = bool(cfg.n_experts)
        wins = self._windows(cfg.n_layers - n_dense, offset=n_dense)

        def one_layer(lp, x, win):
            y, a, _ = self._block(lp, x, positions, win, moe=moe,
                                  kv_chunk=kv_chunk)
            return y, a

        one_layer = maybe_remat(one_layer)

        def body(carry, layer):
            x, aux = carry
            lp, win = layer
            x = constrain_residual(x)
            x, a = one_layer(lp, x, win)
            return (x, aux + a), None

        (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (params["layers"], wins))
        return x, aux

    def _embed(self, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
        x = params["embed"][tokens]
        return x.astype(_dtype(self.cfg))

    def _logits(self, params: Params, h: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        h = apply_norm(params["final_norm"], h, cfg.norm_kind)
        w = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = h @ w.astype(h.dtype)
        return softcap(logits.astype(jnp.float32), cfg.logit_softcap)

    # ------------------------------------------------------------------
    # Training loss
    # ------------------------------------------------------------------
    def loss(self, params: Params, batch: Dict[str, jnp.ndarray]
             ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        tokens, targets = batch["tokens"], batch["targets"]
        mask = batch.get("mask")
        B, S = tokens.shape
        positions = batch.get("mrope_positions") if cfg.mrope else None
        if positions is None:
            positions = make_positions(B, S)
            if cfg.mrope:
                positions = jnp.broadcast_to(positions[None], (3, B, S))
        x = self._embed(params, tokens)
        if "extra_embeds" in batch:        # VLM stub frontend outputs
            x = x + batch["extra_embeds"].astype(x.dtype)
        kv_chunk = _KV_CHUNK if S >= _PREFILL_CHUNK_THRESHOLD else 0
        # §Perf experiment lever: force streaming attention at train time
        # (REPRO_TRAIN_KV_CHUNK=1024) — cuts the f32 score-buffer HBM
        # traffic by ~S/chunk at identical FLOPs.
        env_chunk = int(os.environ.get("REPRO_TRAIN_KV_CHUNK", "0"))
        if env_chunk:
            kv_chunk = env_chunk
        h, aux = self._forward(params, x, positions, kv_chunk=kv_chunk)

        ce, denom = _chunked_ce(lambda hh: self._logits(params, hh), h,
                                targets, mask,
                                chunked=cfg.vocab >= _LOSS_VOCAB_THRESHOLD)
        loss = ce / denom
        if cfg.n_experts:
            loss = loss + 0.01 * aux / cfg.n_layers
        return loss, {"ce": ce / denom, "aux": aux}

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        dt = _dtype(cfg)
        K, hd = cfg.n_kv_heads, cfg.hd()
        n_dense = cfg.first_dense_layers if cfg.n_experts else 0
        n_scan = cfg.n_layers - n_dense
        cache = {
            "k": jnp.zeros((n_scan, batch, max_len, K, hd), dt),
            "v": jnp.zeros((n_scan, batch, max_len, K, hd), dt),
            "len": jnp.zeros((), jnp.int32),
        }
        if n_dense:
            cache["k_dense"] = jnp.zeros((n_dense, batch, max_len, K, hd), dt)
            cache["v_dense"] = jnp.zeros((n_dense, batch, max_len, K, hd), dt)
        return cache

    def prefill(self, params: Params, batch: Dict[str, jnp.ndarray],
                max_len: Optional[int] = None
                ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Process the prompt, build caches, return last-token logits."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        max_len = max_len or S
        positions = batch.get("mrope_positions") if cfg.mrope else None
        if positions is None:
            positions = make_positions(B, S)
            if cfg.mrope:
                positions = jnp.broadcast_to(positions[None], (3, B, S))
        x = self._embed(params, tokens)
        if "extra_embeds" in batch:
            x = x + batch["extra_embeds"].astype(x.dtype)
        kv_chunk = _KV_CHUNK if S >= _PREFILL_CHUNK_THRESHOLD else 0
        cache = self.init_cache(B, max_len)
        zero = jnp.zeros((), jnp.int32)

        n_dense = cfg.first_dense_layers if cfg.n_experts else 0
        for i in range(n_dense):
            lp = jax.tree.map(lambda a: a[i], params["dense_layers"])
            x, _, (kc, vc) = self._block(
                lp, x, positions, self._windows(n_dense)[i], moe=False,
                kv_chunk=kv_chunk,
                cache=(cache["k_dense"][i], cache["v_dense"][i]),
                cache_len=zero)
            cache["k_dense"] = cache["k_dense"].at[i].set(kc)
            cache["v_dense"] = cache["v_dense"].at[i].set(vc)

        moe = bool(cfg.n_experts)
        wins = self._windows(cfg.n_layers - n_dense, offset=n_dense)

        def body(x, layer):
            lp, win, kc, vc = layer
            x = constrain_residual(x)
            x, _, (kc, vc) = self._block(lp, x, positions, win, moe=moe,
                                         kv_chunk=kv_chunk, cache=(kc, vc),
                                         cache_len=zero)
            return x, (kc, vc)

        x, (ks, vs) = lax.scan(body, x,
                               (params["layers"], wins, cache["k"], cache["v"]))
        cache["k"], cache["v"] = ks, vs
        cache["len"] = jnp.full((), S, jnp.int32)
        logits = self._logits(params, x[:, -1:, :])
        return logits[:, 0], cache

    def decode_step(self, params: Params, tokens: jnp.ndarray,
                    cache: Dict[str, Any]
                    ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """One decode step.  tokens: (B, 1)."""
        cfg = self.cfg
        B = tokens.shape[0]
        pos = cache["len"]
        positions = jnp.full((B, 1), pos, jnp.int32)
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[None], (3, B, 1))
        x = self._embed(params, tokens)

        n_dense = cfg.first_dense_layers if cfg.n_experts else 0
        for i in range(n_dense):
            lp = jax.tree.map(lambda a: a[i], params["dense_layers"])
            x, _, (kc, vc) = self._block(
                lp, x, positions, self._windows(n_dense)[i], moe=False,
                cache=(cache["k_dense"][i], cache["v_dense"][i]),
                cache_len=pos)
            cache["k_dense"] = cache["k_dense"].at[i].set(kc)
            cache["v_dense"] = cache["v_dense"].at[i].set(vc)

        moe = bool(cfg.n_experts)
        wins = self._windows(cfg.n_layers - n_dense, offset=n_dense)

        def body(x, layer):
            lp, win, kc, vc = layer
            x, _, (kc, vc) = self._block(lp, x, positions, win, moe=moe,
                                         cache=(kc, vc), cache_len=pos)
            return x, (kc, vc)

        x, (ks, vs) = lax.scan(body, x,
                               (params["layers"], wins, cache["k"], cache["v"]))
        cache = dict(cache, k=ks, v=vs, len=pos + 1)
        logits = self._logits(params, x)
        return logits[:, 0], cache


def _chunked_ce(logits_fn, h: jnp.ndarray, targets: jnp.ndarray,
                mask: Optional[jnp.ndarray], *, chunked: bool
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sum of CE over (possibly seq-chunked) positions + valid count.

    Chunking keeps the (B, chunk, V) logits buffer bounded for 150k-250k
    vocabularies — the full (B, S, V) tensor would dominate HBM.
    """
    B, S, _ = h.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)

    def ce_of(hh, tt, mm):
        lg = logits_fn(hh)                             # (B, c, V) f32
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tt[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mm)

    if not chunked or S % _LOSS_CHUNK or S <= _LOSS_CHUNK:
        return ce_of(h, targets, mask), jnp.maximum(jnp.sum(mask), 1.0)

    n = S // _LOSS_CHUNK
    hc = h.reshape(B, n, _LOSS_CHUNK, -1).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n, _LOSS_CHUNK).transpose(1, 0, 2)
    mc = mask.reshape(B, n, _LOSS_CHUNK).transpose(1, 0, 2)

    ce_chunk = jax.checkpoint(ce_of)   # recompute chunk logits in backward

    def body(tot, xs):
        hh, tt, mm = xs
        return tot + ce_chunk(hh, tt, mm), None

    tot, _ = lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc, mc))
    return tot, jnp.maximum(jnp.sum(mask), 1.0)
