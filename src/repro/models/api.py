"""Model factory + abstract input specs (the dry-run contract).

``build_model(cfg)`` returns the family implementation; ``*_specs``
return ShapeDtypeStruct stand-ins for every model input — weak-type
correct, shardable, no device allocation — which is what
``launch/dryrun.py`` lowers against.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from .encdec import EncDecLM
from .hybrid import HybridLM
from .ssm_lm import MambaLM
from .transformer import DecoderLM

__all__ = ["build_model", "train_batch_specs", "prefill_specs",
           "decode_specs", "params_specs", "make_synthetic_batch"]

_SDS = jax.ShapeDtypeStruct


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        return MambaLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family}")


def params_specs(cfg: ModelConfig):
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {
        "tokens": _SDS((B, S), jnp.int32),
        "targets": _SDS((B, S), jnp.int32),
        "mask": _SDS((B, S), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["frames"] = _SDS((B, cfg.encoder_frames, cfg.d_model),
                               jnp.dtype(cfg.dtype))
    if cfg.mrope:
        batch["mrope_positions"] = _SDS((3, B, S), jnp.int32)
    return batch


def prefill_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {"tokens": _SDS((B, S), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = _SDS((B, cfg.encoder_frames, cfg.d_model),
                               jnp.dtype(cfg.dtype))
    if cfg.mrope:
        batch["mrope_positions"] = _SDS((3, B, S), jnp.int32)
    return batch


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[Any, Any]:
    """(tokens, cache) specs for one decode step with a seq_len cache."""
    B, S = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    tokens = _SDS((B, 1), jnp.int32)
    return tokens, cache


def make_synthetic_batch(cfg: ModelConfig, shape_or_bs, seq=None, key=None):
    """Concrete random batch (for smoke tests / the example trainers)."""
    if isinstance(shape_or_bs, ShapeSpec):
        B, S = shape_or_bs.global_batch, shape_or_bs.seq_len
    else:
        B, S = shape_or_bs, seq
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab, jnp.int32),
        "targets": jax.random.randint(k2, (B, S), 0, cfg.vocab, jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            k3, (B, cfg.encoder_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.mrope:
        pos = jnp.arange(S, dtype=jnp.int32)[None, None, :]
        batch["mrope_positions"] = jnp.broadcast_to(pos, (3, B, S))
    return batch
