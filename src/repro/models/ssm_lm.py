"""Mamba2 language model (attention-free, SSD blocks)."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from ..dist.sharding import constrain, constrain_residual
from ..train.remat import maybe_remat
from .blocks import Params, _dense_init, apply_norm, init_norm, softcap
from .ssm import init_mamba, init_ssm_state, mamba_sequence, mamba_step

__all__ = ["MambaLM"]


class MambaLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "ssm"
        self.cfg = cfg

    # ------------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        keys = jax.random.split(key, cfg.n_layers + 2)

        def layer(k):
            k1, _ = jax.random.split(k)
            return {"ln": init_norm(cfg, dt), "mamba": init_mamba(k1, cfg, dt)}

        params: Params = {
            "embed": _dense_init(keys[0], (cfg.vocab, cfg.d_model), dt),
            "final_norm": init_norm(cfg, dt),
            "layers": jax.vmap(layer)(jnp.stack(keys[2:2 + cfg.n_layers])),
        }
        if not cfg.tie_embeddings:
            params["head"] = _dense_init(keys[1], (cfg.d_model, cfg.vocab), dt)
        return params

    # ------------------------------------------------------------------
    def _logits(self, params, h):
        cfg = self.cfg
        h = apply_norm(params["final_norm"], h, cfg.norm_kind)
        w = params["embed"].T if cfg.tie_embeddings else params["head"]
        return softcap((h @ w.astype(h.dtype)).astype(jnp.float32),
                       cfg.logit_softcap)

    def _forward(self, params, x, states=None):
        cfg = self.cfg

        def one_layer(lp, x, st):
            h = apply_norm(lp["ln"], x, cfg.norm_kind)
            y, st_new = mamba_sequence(lp["mamba"], cfg, h, st)
            return x + y, st_new

        one_layer = maybe_remat(one_layer)

        def body(carry, layer):
            x = carry
            lp, st = layer
            x = constrain_residual(x)
            x, st_new = one_layer(lp, x, st)
            return x, st_new

        x, new_states = lax.scan(body, x, (params["layers"], states))
        return x, new_states

    # ------------------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        tokens, targets = batch["tokens"], batch["targets"]
        mask = batch.get("mask", jnp.ones_like(tokens, jnp.float32))
        x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
        states = self._stacked_states(tokens.shape[0])
        h, _ = self._forward(params, x, states)
        logits = self._logits(params, h)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        ce = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce, {"ce": ce}

    # ------------------------------------------------------------------
    def _stacked_states(self, batch: int):
        cfg = self.cfg
        one = init_ssm_state(cfg, batch, jnp.dtype(cfg.dtype))
        return jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)

    def init_cache(self, batch: int, max_len: int) -> Dict[str, Any]:
        st = self._stacked_states(batch)
        st["len"] = jnp.zeros((), jnp.int32)
        return st

    def prefill(self, params, batch, max_len: Optional[int] = None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
        states = self._stacked_states(B)
        h, new_states = self._forward(params, x, states)
        new_states["len"] = jnp.full((), S, jnp.int32)
        logits = self._logits(params, h[:, -1:, :])
        return logits[:, 0], new_states

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
        pos = cache["len"]

        def body(x, layer):
            lp, st = layer
            h = apply_norm(lp["ln"], x, cfg.norm_kind)
            y, st_new = mamba_step(lp["mamba"], cfg, h, st)
            return x + y, st_new

        states = {k: cache[k] for k in ("ssm", "conv")}
        x, new_states = lax.scan(body, x, (params["layers"], states))
        cache = dict(new_states, len=pos + 1)
        logits = self._logits(params, x)
        return logits[:, 0], cache
