"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Implements the chunked SSD dual form for training/prefill (quadratic
within a chunk, linear across chunks) and the O(1)-per-token recurrence
for decode.  The per-chunk einsums are MXU-shaped (chunk x chunk and
chunk x state matmuls), which is what the Pallas kernel in
``repro.kernels.ssd_scan`` tiles explicitly; this module is the XLA
reference path used by the dry-run.

Shapes: heads H = d_inner / head_dim P, single B/C group (G=1), state N.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .blocks import Params, _dense_init, apply_norm

__all__ = ["init_mamba", "mamba_sequence", "mamba_step", "init_ssm_state"]


def init_mamba(key, cfg: ModelConfig, dtype) -> Params:
    d, di = cfg.d_model, cfg.d_inner()
    N, H, K = cfg.ssm_state, cfg.ssm_heads(), cfg.conv_kernel
    conv_ch = di + 2 * N                       # x + B + C go through conv
    ks = jax.random.split(key, 4)
    out_std = 0.02 / math.sqrt(2 * max(1, cfg.n_layers))
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di + 2 * N + H), dtype),
        "conv_w": _dense_init(ks[1], (K, conv_ch), dtype, std=0.2),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
        "out_proj": _dense_init(ks[2], (di, d), dtype, std=out_std),
    }


def _split_proj(cfg: ModelConfig, proj: jnp.ndarray):
    di, N, H = cfg.d_inner(), cfg.ssm_state, cfg.ssm_heads()
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d.  xbc: (B, S, C), w: (K, C).

    Returns (y, new_state) where state carries the trailing K-1 inputs.
    """
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    ext = jnp.concatenate([state, xbc], axis=1)                # (B, K-1+S, C)
    y = sum(ext[:, i:i + xbc.shape[1], :] * w[i] for i in range(K))
    new_state = ext[:, -(K - 1):, :] if K > 1 else state
    return jax.nn.silu(y + b), new_state


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H, P, N, K = (cfg.ssm_heads(), cfg.ssm_head_dim, cfg.ssm_state,
                  cfg.conv_kernel)
    di = cfg.d_inner()
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, di + 2 * N), dtype),
    }


def _ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                 h0: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD dual-form over chunks.

    x:  (B, S, H, P)   inputs per head
    dt: (B, S, H)      positive step sizes
    A:  (H,)           negative decay rates
    Bm, Cm: (B, S, N)  input/output projections (G=1, shared over heads)
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    n_chunks = (S + Q - 1) // Q
    pad = n_chunks * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    def reshape_c(t):
        return t.reshape((Bsz, n_chunks, Q) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1)))

    xc, dtc, Bc, Cc = map(reshape_c, (x, dt, Bm, Cm))   # leading n_chunks

    a = dtc * A[None, None, :]                      # (c, B, Q, H) log-decay
    cum = jnp.cumsum(a, axis=2)                     # within-chunk cumsum

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def chunk_step(h, inp):
        xq, dtq, bq, cq, aq, cumq = inp
        # decay matrix L[i, j] = exp(cum_i - cum_j) for i >= j else 0.
        # Mask BEFORE exp: masked entries have diff > 0 and overflow to
        # inf, and where(c, inf, 0) poisons the backward with 0*inf=NaN.
        diff = cumq[:, :, None, :] - cumq[:, None, :, :]     # (B,Q,Q,H)
        iq = jnp.arange(xq.shape[1])
        causal = (iq[:, None] >= iq[None, :])[None, :, :, None]
        L = jnp.exp(jnp.where(causal, diff, -1e30))
        # intra-chunk: scores (B,Q,Q) from C_i . B_j; weight by L and dt_j
        s = jnp.einsum("bin,bjn->bij", cq, bq)               # (B,Q,Q)
        w = s[:, :, :, None] * L * dtq[:, None, :, :]        # (B,Q,Q,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xq)
        # inter-chunk: contribution of the carried state
        decay_in = jnp.exp(cumq)                             # (B,Q,H)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cq, h, decay_in)
        y = y_intra + y_inter
        # state update: h' = exp(sum a) h + sum_j exp(cum_Q - cum_j) dt_j B_j x_j
        total = cumq[:, -1, :]                               # (B,H)
        rem = jnp.exp(total[:, None, :] - cumq)              # (B,Q,H)
        contrib = jnp.einsum("bjh,bjn,bjhp->bhpn", rem * dtq, bq, xq)
        h_new = jnp.exp(total)[:, :, None, None] * h + contrib
        return h_new, y

    h_fin, yc = lax.scan(chunk_step, h0,
                         (xc.astype(jnp.float32), dtc, Bc.astype(jnp.float32),
                          Cc.astype(jnp.float32), a, cum))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bsz, n_chunks * Q, H, P)
    return y[:, :S], h_fin


def mamba_sequence(p: Params, cfg: ModelConfig, u: jnp.ndarray,
                   state: Optional[Dict[str, jnp.ndarray]] = None
                   ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence Mamba2 block (training / prefill).

    u: (B, S, d_model) -> (y, final_state).
    """
    B, S, d = u.shape
    di, N, H, P = cfg.d_inner(), cfg.ssm_state, cfg.ssm_heads(), cfg.ssm_head_dim
    proj = u @ p["in_proj"]
    z, xbc, dt = _split_proj(cfg, proj)
    conv_state = state["conv"] if state else None
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                      # (H,)
    xh = xs.reshape(B, S, H, P)
    h0 = state["ssm"] if state else None
    y, h_fin = _ssd_chunked(xh.astype(jnp.float32), dt, A, Bm, Cm,
                            cfg.ssm_chunk, h0)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = apply_norm({"scale": p["norm_scale"]}, y, "rmsnorm")
    out = y @ p["out_proj"]
    return out, {"ssm": h_fin, "conv": conv_state}


def mamba_step(p: Params, cfg: ModelConfig, u: jnp.ndarray,
               state: Dict[str, jnp.ndarray]
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token recurrent step (decode).  u: (B, 1, d_model)."""
    B, _, d = u.shape
    di, N, H, P = cfg.d_inner(), cfg.ssm_state, cfg.ssm_heads(), cfg.ssm_head_dim
    proj = u[:, 0] @ p["in_proj"]                                 # (B, .)
    z, xbc, dt = _split_proj(cfg, proj)
    # conv step: append to rolling window
    K = p["conv_w"].shape[0]
    win = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)  # (B,K,C)
    y_conv = jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"]
    xbc1 = jax.nn.silu(y_conv)
    new_conv = win[:, 1:, :]
    xs, Bm, Cm = jnp.split(xbc1, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    h = state["ssm"]                                              # (B,H,P,N)
    decay = jnp.exp(dt * A)[:, :, None, None]
    h_new = h * decay + jnp.einsum("bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32), xh)
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h_new)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, di).astype(u.dtype) * jax.nn.silu(z)
    y = apply_norm({"scale": p["norm_scale"]}, y, "rmsnorm")
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"ssm": h_new, "conv": new_conv}
