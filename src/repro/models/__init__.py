"""Model zoo: dense/MoE transformers, Mamba2 SSM, Zamba2 hybrid, Whisper
encoder-decoder, VLM backbone — all pure-functional JAX."""

from .api import (build_model, decode_specs, make_synthetic_batch,
                  params_specs, prefill_specs, train_batch_specs)
from .encdec import EncDecLM
from .hybrid import HybridLM
from .ssm_lm import MambaLM
from .transformer import DecoderLM

__all__ = ["build_model", "DecoderLM", "MambaLM", "HybridLM", "EncDecLM",
           "params_specs", "train_batch_specs", "prefill_specs",
           "decode_specs", "make_synthetic_batch"]
