"""Zamba2-style hybrid LM: Mamba2 backbone + one SHARED attention block.

The shared block (attention + gated MLP, one copy of weights) fires
before every ``shared_attn_every``-th group of Mamba layers — the 54
Mamba layers form 9 super-blocks of 6, and the scan runs over
super-blocks so the weight reuse is structural (one set of attention
parameters referenced from every scan iteration = a genuinely non-trivial
TMG transition for COSMOS, DESIGN.md Section 4).

Each invocation site keeps its own KV cache (weights are shared, caches
are not).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from ..dist.sharding import constrain, constrain_residual
from ..train.remat import maybe_remat
from .blocks import (Params, _dense_init, apply_attention, apply_mlp,
                     apply_norm, init_attention, init_mlp, init_norm,
                     make_positions, softcap)
from .ssm import init_mamba, init_ssm_state, mamba_sequence, mamba_step

__all__ = ["HybridLM"]


class HybridLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "hybrid" and cfg.shared_attn_every > 0
        assert cfg.n_layers % cfg.shared_attn_every == 0
        self.cfg = cfg
        self.n_sites = cfg.n_layers // cfg.shared_attn_every

    # ------------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        keys = jax.random.split(key, cfg.n_layers + 4)

        def layer(k):
            return {"ln": init_norm(cfg, dt), "mamba": init_mamba(k, cfg, dt)}

        g, e = self.n_sites, cfg.shared_attn_every
        stacked = jax.vmap(layer)(jnp.stack(keys[4:4 + cfg.n_layers]))
        # reshape (L, ...) -> (sites, every, ...) for the super-block scan
        stacked = jax.tree.map(
            lambda a: a.reshape((g, e) + a.shape[1:]), stacked)

        params: Params = {
            "embed": _dense_init(keys[0], (cfg.vocab, cfg.d_model), dt),
            "final_norm": init_norm(cfg, dt),
            "layers": stacked,
            "shared_ln1": init_norm(cfg, dt),
            "shared_attn": init_attention(keys[1], cfg, dt),
            "shared_ln2": init_norm(cfg, dt),
            "shared_mlp": init_mlp(keys[2], cfg, dt),
        }
        if not cfg.tie_embeddings:
            params["head"] = _dense_init(keys[3], (cfg.d_model, cfg.vocab), dt)
        return params

    # ------------------------------------------------------------------
    def _logits(self, params, h):
        cfg = self.cfg
        h = apply_norm(params["final_norm"], h, cfg.norm_kind)
        w = params["embed"].T if cfg.tie_embeddings else params["head"]
        return softcap((h @ w.astype(h.dtype)).astype(jnp.float32),
                       cfg.logit_softcap)

    def _shared_block(self, params, x, positions, *, cache=None,
                      cache_len=None, kv_chunk=0):
        cfg = self.cfg
        h = apply_norm(params["shared_ln1"], x, cfg.norm_kind)
        a, new_cache = apply_attention(params["shared_attn"], cfg, h,
                                       positions, cache=cache,
                                       cache_len=cache_len, causal=True,
                                       kv_chunk=kv_chunk)
        x = x + a
        h = apply_norm(params["shared_ln2"], x, cfg.norm_kind)
        return x + apply_mlp(params["shared_mlp"], cfg, h), new_cache

    # ------------------------------------------------------------------
    def _forward(self, params, x, positions, mamba_states, *,
                 attn_caches=None, cache_len=None, kv_chunk=0, step=False):
        cfg = self.cfg

        def super_block(carry, xs):
            x = carry
            if attn_caches is None:
                lp, st = xs
                kc = vc = None
            else:
                lp, st, kc, vc = xs
            x = constrain_residual(x)
            x, new_cache = self._shared_block(
                params, x, positions,
                cache=None if kc is None else (kc, vc),
                cache_len=cache_len, kv_chunk=kv_chunk)

            def mamba_layer(x, inner):
                ilp, ist = inner

                def inner_fn(ilp, x, ist):
                    h = apply_norm(ilp["ln"], x, cfg.norm_kind)
                    fn = mamba_step if step else mamba_sequence
                    y, ist_new = fn(ilp["mamba"], cfg, h, ist)
                    return x + y, ist_new

                return maybe_remat(inner_fn)(ilp, x, ist)

            x, st_new = lax.scan(mamba_layer, x, (lp, st))
            out = (st_new,) if new_cache is None else (st_new,) + new_cache
            return x, out

        xs = (params["layers"], mamba_states)
        if attn_caches is not None:
            xs = xs + (attn_caches["k"], attn_caches["v"])
        x, outs = lax.scan(super_block, x, xs)
        new_states = outs[0]
        new_caches = None
        if attn_caches is not None:
            new_caches = {"k": outs[1], "v": outs[2]}
        return x, new_states, new_caches

    # ------------------------------------------------------------------
    def _stacked_states(self, batch: int):
        cfg = self.cfg
        one = init_ssm_state(cfg, batch, jnp.dtype(cfg.dtype))
        g, e = self.n_sites, cfg.shared_attn_every
        return jax.tree.map(
            lambda a: jnp.zeros((g, e) + a.shape, a.dtype), one)

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        tokens, targets = batch["tokens"], batch["targets"]
        mask = batch.get("mask", jnp.ones_like(tokens, jnp.float32))
        B, S = tokens.shape
        x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
        positions = make_positions(B, S)
        kv_chunk = 1024 if S >= 16384 else 0
        h, _, _ = self._forward(params, x, positions,
                                self._stacked_states(B), kv_chunk=kv_chunk)
        logits = self._logits(params, h)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        ce = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce, {"ce": ce}

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        st = self._stacked_states(batch)
        K, hd = cfg.n_kv_heads, cfg.hd()
        return {
            "ssm": st["ssm"], "conv": st["conv"],
            "k": jnp.zeros((self.n_sites, batch, max_len, K, hd), dt),
            "v": jnp.zeros((self.n_sites, batch, max_len, K, hd), dt),
            "len": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, batch, max_len: Optional[int] = None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        max_len = max_len or S
        x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
        positions = make_positions(B, S)
        cache = self.init_cache(B, max_len)
        kv_chunk = 1024 if S >= 16384 else 0
        h, st, kv = self._forward(
            params, x, positions, {"ssm": cache["ssm"], "conv": cache["conv"]},
            attn_caches={"k": cache["k"], "v": cache["v"]},
            cache_len=jnp.zeros((), jnp.int32), kv_chunk=kv_chunk)
        logits = self._logits(params, h[:, -1:, :])
        return logits[:, 0], {"ssm": st["ssm"], "conv": st["conv"],
                              "k": kv["k"], "v": kv["v"],
                              "len": jnp.full((), S, jnp.int32)}

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        B = tokens.shape[0]
        pos = cache["len"]
        positions = jnp.full((B, 1), pos, jnp.int32)
        x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
        h, st, kv = self._forward(
            params, x, positions, {"ssm": cache["ssm"], "conv": cache["conv"]},
            attn_caches={"k": cache["k"], "v": cache["v"]},
            cache_len=pos, step=True)
        logits = self._logits(params, h)
        return logits[:, 0], {"ssm": st["ssm"], "conv": st["conv"],
                              "k": kv["k"], "v": kv["v"], "len": pos + 1}
