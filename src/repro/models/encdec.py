"""Whisper-style encoder-decoder (audio backbone only, per assignment).

The conv audio frontend is a STUB: ``input_specs`` provides precomputed
frame embeddings (B, frames, d_model) — the assignment's
"modality frontend is a STUB (input_specs() provides precomputed
frame/patch embeddings)".  Positions use on-the-fly sinusoidal embeddings
on both sides so the assigned 32k decoder shapes need no learned
position table (DESIGN.md notes this deviation from Whisper's learned
decoder positions).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from ..dist.sharding import constrain, constrain_residual
from ..train.remat import maybe_remat
from .blocks import (Params, _dense_init, apply_attention, apply_mlp,
                     apply_norm, init_attention, init_mlp, init_norm,
                     make_positions, softcap)

__all__ = ["EncDecLM"]


def _sinusoidal(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """(B, S) int positions -> (B, S, d) float32 sinusoidal embeddings."""
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "encdec"
        self.cfg = cfg

    # ------------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        n_enc, n_dec = cfg.n_encoder_layers, cfg.n_layers
        keys = jax.random.split(key, 4)

        def enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {"ln1": init_norm(cfg, dt),
                    "attn": init_attention(k1, cfg, dt),
                    "ln2": init_norm(cfg, dt),
                    "mlp": init_mlp(k2, cfg, dt)}

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"ln1": init_norm(cfg, dt),
                    "self_attn": init_attention(k1, cfg, dt),
                    "ln_x": init_norm(cfg, dt),
                    "cross_attn": init_attention(k2, cfg, dt),
                    "ln2": init_norm(cfg, dt),
                    "mlp": init_mlp(k3, cfg, dt)}

        return {
            "embed": _dense_init(keys[0], (cfg.vocab, cfg.d_model), dt),
            "enc_layers": jax.vmap(enc_layer)(jax.random.split(keys[1], n_enc)),
            "enc_norm": init_norm(cfg, dt),
            "dec_layers": jax.vmap(dec_layer)(jax.random.split(keys[2], n_dec)),
            "final_norm": init_norm(cfg, dt),
        }

    # ------------------------------------------------------------------
    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """frames: (B, F, d) stub-frontend embeddings -> encoder states."""
        cfg = self.cfg
        B, F, _ = frames.shape
        pos = make_positions(B, F)
        x = frames.astype(jnp.dtype(cfg.dtype))
        x = x + _sinusoidal(pos, cfg.d_model).astype(x.dtype)

        def one_layer(lp, x):
            h = apply_norm(lp["ln1"], x, cfg.norm_kind)
            a, _ = apply_attention(lp["attn"], cfg, h, pos, causal=False)
            x = x + a
            h = apply_norm(lp["ln2"], x, cfg.norm_kind)
            return x + apply_mlp(lp["mlp"], cfg, h)

        one_layer = maybe_remat(one_layer)

        def body(x, lp):
            x = constrain_residual(x)
            return one_layer(lp, x), None

        x, _ = lax.scan(body, x, params["enc_layers"])
        return apply_norm(params["enc_norm"], x, cfg.norm_kind)

    def _cross_kv(self, params, enc: jnp.ndarray):
        """Precompute per-decoder-layer cross-attention K/V (stacked L)."""
        cfg = self.cfg
        B, F, _ = enc.shape
        K, hd = cfg.n_kv_heads, cfg.hd()

        def per_layer(lp):
            k = (enc @ lp["cross_attn"]["wk"]).reshape(B, F, K, hd)
            v = (enc @ lp["cross_attn"]["wv"]).reshape(B, F, K, hd)
            return k, v

        return jax.vmap(per_layer)(params["dec_layers"])

    def _dec_block(self, lp, x, positions, enc_pos, *, cross_kv,
                   self_cache=None, cache_len=None, kv_chunk=0):
        cfg = self.cfg
        h = apply_norm(lp["ln1"], x, cfg.norm_kind)
        a, new_cache = apply_attention(lp["self_attn"], cfg, h, positions,
                                       cache=self_cache, cache_len=cache_len,
                                       causal=True, kv_chunk=kv_chunk)
        x = x + a
        h = apply_norm(lp["ln_x"], x, cfg.norm_kind)
        c, _ = apply_attention(lp["cross_attn"], cfg, h, positions,
                               kv=cross_kv, kv_positions=enc_pos,
                               causal=False)
        x = x + c
        h = apply_norm(lp["ln2"], x, cfg.norm_kind)
        return x + apply_mlp(lp["mlp"], cfg, h), new_cache

    def _decode_seq(self, params, tokens, enc, *, caches=None, cache_len=None,
                    kv_chunk=0):
        cfg = self.cfg
        B, S = tokens.shape
        offset = 0 if cache_len is None else cache_len
        positions = make_positions(B, S, offset=offset)
        enc_pos = make_positions(B, enc.shape[1])
        x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
        x = x + _sinusoidal(positions, cfg.d_model).astype(x.dtype)
        ck, cv = self._cross_kv(params, enc) if caches is None else (
            caches["cross_k"], caches["cross_v"])

        if caches is None:
            def one_layer(lp, x, k1, v1):
                y, _ = self._dec_block(x=x, lp=lp, positions=positions,
                                       enc_pos=enc_pos, cross_kv=(k1, v1),
                                       kv_chunk=kv_chunk)
                return y

            one_layer = maybe_remat(one_layer)

            def body(x, layer):
                lp, k1, v1 = layer
                x = constrain_residual(x)
                return one_layer(lp, x, k1, v1), None
            x, _ = lax.scan(body, x, (params["dec_layers"], ck, cv))
            new_caches = None
        else:
            def body(x, layer):
                lp, k1, v1, sk, sv = layer
                x = constrain_residual(x)
                x, new_c = self._dec_block(x=x, lp=lp, positions=positions,
                                           enc_pos=enc_pos, cross_kv=(k1, v1),
                                           self_cache=(sk, sv),
                                           cache_len=cache_len,
                                           kv_chunk=kv_chunk)
                return x, new_c
            x, (ks, vs) = lax.scan(body, x, (params["dec_layers"], ck, cv,
                                             caches["k"], caches["v"]))
            new_caches = dict(caches, k=ks, v=vs)
        return x, new_caches

    def _logits(self, params, h):
        cfg = self.cfg
        h = apply_norm(params["final_norm"], h, cfg.norm_kind)
        return (h @ params["embed"].T.astype(h.dtype)).astype(jnp.float32)

    # ------------------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        tokens, targets = batch["tokens"], batch["targets"]
        mask = batch.get("mask", jnp.ones_like(tokens, jnp.float32))
        enc = self.encode(params, batch["frames"])
        kv_chunk = 1024 if tokens.shape[1] >= 16384 else 0
        h, _ = self._decode_seq(params, tokens, enc, kv_chunk=kv_chunk)
        logits = self._logits(params, h)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        ce = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce, {"ce": ce}

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        K, hd = cfg.n_kv_heads, cfg.hd()
        L, F = cfg.n_layers, cfg.encoder_frames
        return {
            "k": jnp.zeros((L, batch, max_len, K, hd), dt),
            "v": jnp.zeros((L, batch, max_len, K, hd), dt),
            "cross_k": jnp.zeros((L, batch, F, K, hd), dt),
            "cross_v": jnp.zeros((L, batch, F, K, hd), dt),
            "len": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, batch, max_len: Optional[int] = None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        max_len = max_len or S
        enc = self.encode(params, batch["frames"])
        caches = self.init_cache(B, max_len)
        ck, cv = self._cross_kv(params, enc)
        caches["cross_k"], caches["cross_v"] = ck, cv
        kv_chunk = 1024 if S >= 16384 else 0
        h, caches = self._decode_seq(params, tokens, enc, caches=caches,
                                     cache_len=jnp.zeros((), jnp.int32),
                                     kv_chunk=kv_chunk)
        caches["len"] = jnp.full((), S, jnp.int32)
        logits = self._logits(params, h[:, -1:, :])
        return logits[:, 0], caches

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        B = tokens.shape[0]
        pos = cache["len"]
        # encoder states are folded into cross_k/cross_v; pass a dummy enc
        enc_dummy = jnp.zeros((B, cfg.encoder_frames, cfg.d_model),
                              jnp.dtype(cfg.dtype))
        h, cache = self._decode_seq(params, tokens, enc_dummy, caches=cache,
                                    cache_len=pos)
        cache["len"] = pos + 1
        logits = self._logits(params, h)
        return logits[:, 0], cache
