"""LR schedules: linear warmup + cosine decay (the zoo default)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine"]


def warmup_cosine(step, *, warmup: int = 100, total: int = 10000,
                  floor: float = 0.1):
    """Scale factor in [floor, 1]: linear warmup then cosine to floor."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(1.0, float(warmup)), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(1.0, float(total - warmup)),
                    0.0, 1.0)
    cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return warm * cos
