"""AdamW with decoupled weight decay, fp32 moments, global-norm clipping.

Pure pytree implementation (no optax dependency).  Moments are kept in
float32 regardless of parameter dtype (mixed-precision training); the
ZeRO-1 sharding of the moment pytree is an annotation applied by
``repro.dist.sharding.zero1_spec`` at pjit time, not a property of the
math here.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt", "apply_updates",
           "global_norm", "clip_by_global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # parameters whose path matches this regex get no weight decay
    no_decay_pattern: str = r"(bias|scale|norm|A_log|D$|dt_bias)"


class OptState(NamedTuple):
    step: jnp.ndarray          # ()
    mu: Any                    # first moments  (fp32 pytree)
    nu: Any                    # second moments (fp32 pytree)


def init_opt(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any, state: OptState,
                  lr_scale: jnp.ndarray | float = 1.0,
                  decay_mask: Optional[Any] = None
                  ) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    """One AdamW step.  grads may be any dtype; math runs in fp32 and
    parameters are cast back to their storage dtype."""
    import re
    if decay_mask is None:
        pat = re.compile(cfg.no_decay_pattern)
        from ..utils import keystr_path
        paths = jax.tree_util.tree_map_with_path(
            lambda kp, _: keystr_path(kp), params)
        decay_mask = jax.tree.map(lambda p: 0.0 if pat.search(p) else 1.0, paths)

    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v, wd):
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * wd * pf)
        return pf.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_w = jax.tree.leaves(decay_mask)
    out = [upd(p, g, m, v, w) for p, g, m, v, w in
           zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm}
