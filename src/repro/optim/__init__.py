from .adamw import (AdamWConfig, OptState, apply_updates, clip_by_global_norm,
                    global_norm, init_opt)
from .quantized import QuantOptState, apply_updates_q8, init_opt_q8
from .schedule import warmup_cosine

__all__ = ["AdamWConfig", "OptState", "init_opt", "apply_updates",
           "global_norm", "clip_by_global_norm", "warmup_cosine",
           "QuantOptState", "init_opt_q8", "apply_updates_q8"]
