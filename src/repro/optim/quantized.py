"""8-bit AdamW moments (row-wise quantized state).

The kimi-k2 §Perf lever: fp32 AdamW moments for 1T params are 8 TB —
four times the weights.  Row-wise int8 moments (absmax scale per last-dim
row) cut that to ~2.03 TB while the update math stays fp32: states are
dequantized, updated, and requantized inside the step.

Row-wise (not flat 256-blocks) is the deliberate TPU/SPMD choice: the
int8 tensor keeps the PARAMETER's shape, so it inherits the parameter's
sharding verbatim and the scales drop the last dim — no reshape ever
crosses a shard boundary (the flat-block variant trips the SPMD
partitioner on 2D-sharded expert weights; see EXPERIMENTS.md §Perf).
Convergence parity is asserted in tests on a quadratic and on a real LM.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .adamw import AdamWConfig, clip_by_global_norm

__all__ = ["QuantOptState", "init_opt_q8", "apply_updates_q8",
           "quantize_rows", "dequantize_rows"]


def quantize_rows(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., n) -> (int8 same shape, f32 scales (...,))."""
    xf = x.astype(jnp.float32)
    if xf.ndim == 0:
        xf = xf[None]
        s = jnp.maximum(jnp.abs(xf), 1e-12) / 127.0
        return jnp.clip(jnp.round(xf / s), -127, 127).astype(jnp.int8)[0], s[0]
    s = jnp.max(jnp.abs(xf), axis=-1) / 127.0
    s = jnp.maximum(s, 1e-20)
    q = jnp.clip(jnp.round(xf / s[..., None]), -127, 127).astype(jnp.int8)
    return q, s


def dequantize_rows(q: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    if q.ndim == 0:
        return q.astype(jnp.float32) * s
    return q.astype(jnp.float32) * s[..., None]


class QuantOptState(NamedTuple):
    step: jnp.ndarray
    mu_q: Any          # int8 pytree, param-shaped
    mu_s: Any          # fp32 row scales, param.shape[:-1]
    nu_q: Any
    nu_s: Any


def init_opt_q8(params: Any) -> QuantOptState:
    mu_q = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.int8), params)
    mu_s = jax.tree.map(
        lambda p: jnp.zeros(p.shape[:-1] if p.ndim else (), jnp.float32),
        params)
    return QuantOptState(step=jnp.zeros((), jnp.int32),
                         mu_q=mu_q, mu_s=mu_s,
                         nu_q=jax.tree.map(jnp.copy, mu_q),
                         nu_s=jax.tree.map(jnp.copy, mu_s))


def apply_updates_q8(cfg: AdamWConfig, params: Any, grads: Any,
                     state: QuantOptState, lr_scale=1.0
                     ) -> Tuple[Any, QuantOptState, Dict[str, jnp.ndarray]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mq, ms, vq, vs):
        m = dequantize_rows(mq, ms)
        v = dequantize_rows(vq, vs)
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        delta = (m / b1c) / (jnp.sqrt(jnp.maximum(v, 0.0) / b2c) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        mq2, ms2 = quantize_rows(m)
        vq2, vs2 = quantize_rows(v)
        return pf.astype(p.dtype), mq2, ms2, vq2, vs2

    flat_p, treedef = jax.tree.flatten(params)
    out = [upd(p, g, mq, ms, vq, vs) for p, g, mq, ms, vq, vs in zip(
        flat_p, jax.tree.leaves(grads),
        jax.tree.leaves(state.mu_q), jax.tree.leaves(state.mu_s),
        jax.tree.leaves(state.nu_q), jax.tree.leaves(state.nu_s))]
    unf = lambda i: treedef.unflatten([o[i] for o in out])
    return unf(0), QuantOptState(step, unf(1), unf(2), unf(3), unf(4)), \
        {"grad_norm": gnorm}
