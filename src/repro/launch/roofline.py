"""Roofline report generator: artifacts/dryrun/*.json -> markdown tables.

    PYTHONPATH=src python -m repro.launch.roofline > artifacts/roofline.md

Per (arch x shape x mesh): the three roofline terms in seconds, the
dominant bottleneck, MODEL_FLOPS / HLO_FLOPs (useful-compute fraction),
HBM fit, and a one-line "what would move the dominant term" note.
"""

from __future__ import annotations

import glob
import json
import os
import sys

from ..configs import get_config, get_shape
from .hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "dryrun")

_MOVES = {
    "compute": "raise MXU utilization: larger per-device tiles, fewer "
               "pad/transpose ops, fuse elementwise chains",
    "memory": "cut HBM traffic: more microbatches / tighter remat, bf16 "
              "accumulation, fuse attention (flash kernel), avoid "
              "recompute re-reads",
    "collective": "cut ICI traffic: sequence-parallel residuals "
                  "(reduce-scatter instead of all-gather), overlap "
                  "collectives with compute, gradient compression on the "
                  "pod axis",
}


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch


def ideal_mem_bytes(arch: str, shape_name: str, devices: int,
                    microbatches: int) -> float:
    """Analytic minimum HBM traffic per device per step (lower bound):
    weight reads (x3 per microbatch for fwd/bwd/remat on train; x1 for
    serving) + activation residual stream + KV/state traffic.  The
    HLO-derived bytes are an upper bound (CPU fusion boundaries
    over-materialize vs TPU); truth lies between."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    tp = 16
    dp = devices // tp
    n_act = cfg.active_param_count()
    w_dev = 2.0 * n_act / tp
    B, S = shape.global_batch, shape.seq_len
    d, L = cfg.d_model, max(cfg.n_layers, 1)
    act = L * (B / dp) * S * d * 2.0 * 4   # residual r/w fwd+bwd
    if shape.kind == "train":
        opt = 12.0 * cfg.param_count() / (tp * dp)
        return 3.0 * w_dev * max(1, microbatches) + act + opt
    if shape.kind == "prefill":
        return w_dev + act / 2
    # decode: weights + full cache read
    hd = cfg.hd() if cfg.n_heads else 0
    cache = 2.0 * L * B * S * cfg.n_kv_heads * hd * 2.0 / devices
    return w_dev + cache


def load(directory: str = ART):
    rows = []
    for f in sorted(glob.glob(os.path.join(directory, "*.json"))):
        if "__" in os.path.basename(f).replace(".json", "")[-6:]:
            pass
        rows.append(json.load(open(f)))
    return rows


def render(rows, out=sys.stdout):
    from .hlo_analysis import HBM_BW
    w = out.write
    w("| arch | shape | mesh | compute s | memory s (hi/lo) | "
      "collective s | bound | useful/HLO | roofline frac (lo–hi) | "
      "HBM GB | fits |\n")
    w("|---|---|---|---|---|---|---|---|---|---|---|\n")
    for r in rows:
        if r["status"] == "skip":
            w(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
              f"SKIP | — | — | — | ({r['skip_reason'][:44]}…) |\n")
            continue
        if r["status"] != "ok":
            w(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
              f"ERROR | — | — | — | — |\n")
            continue
        ro = r["roofline"]
        mf = model_flops(r["arch"], r["shape"])
        hlo = r["cost"]["flops_per_device"] * r["devices"]
        ratio = mf / hlo if hlo else 0.0
        mem = r["memory"]
        hbm = (mem["argument_bytes"] + mem["temp_bytes"]
               + mem["output_bytes"]) / 1e9
        t_mem_lo = ideal_mem_bytes(r["arch"], r["shape"], r["devices"],
                                   r.get("microbatches", 1)) / HBM_BW
        tc = ro["t_compute_s"]
        hi_bound = max(tc, ro["t_memory_s"], ro["t_collective_s"])
        lo_bound = max(tc, t_mem_lo, ro["t_collective_s"])
        frac_lo = tc / hi_bound if hi_bound else 0.0   # pessimistic traffic
        frac_hi = tc / lo_bound if lo_bound else 0.0   # analytic-min traffic
        w(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
          f"| {tc:.4f} | {ro['t_memory_s']:.4f}/{t_mem_lo:.4f} "
          f"| {ro['t_collective_s']:.4f} | **{ro['bound']}** "
          f"| {ratio:.2f} | {frac_lo:.0%}–{frac_hi:.0%} "
          f"| {hbm:.1f} | {'Y' if hbm <= 16 else 'N'} |\n")
    w("\nBottleneck remedies:\n")
    for k, v in _MOVES.items():
        w(f"- **{k}**: {v}\n")


def main():
    render(load())


if __name__ == "__main__":
    main()
