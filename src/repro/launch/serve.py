"""Serving launcher: batched engine over any zoo arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b-smoke \\
        --requests 16 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..data import SyntheticLM
from ..models import build_model
from ..serve import ServeEngine


def run(arch: str, *, requests: int = 16, slots: int = 8,
        prompt_len: int = 32, max_new: int = 16, temperature: float = 0.0,
        seed: int = 0):
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    src = SyntheticLM(vocab=cfg.vocab, seed=seed)
    prompts = src.batch(step=0, shard=0, n_shards=1, batch=requests,
                        seq=prompt_len)["tokens"]

    eng = ServeEngine(model, params, slots=slots, prompt_len=prompt_len,
                      max_new=max_new, temperature=temperature)
    for rid in range(requests):
        eng.submit(rid, prompts[rid])
    t0 = time.time()
    results = eng.run()
    wall = time.time() - t0
    toks = sum(len(v) for v in results.values())
    print(f"[serve] {cfg.name}: {requests} requests x {max_new} tokens in "
          f"{wall:.2f}s = {toks / wall:.1f} tok/s "
          f"(slots={slots}, greedy={temperature <= 0})")
    print(f"[serve] sample output (rid 0): {results[0][:12]}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    a = ap.parse_args()
    run(a.arch, requests=a.requests, slots=a.slots, prompt_len=a.prompt_len,
        max_new=a.max_new, temperature=a.temperature)


if __name__ == "__main__":
    main()
