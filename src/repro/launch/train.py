"""Training launcher: data pipeline + step + checkpoints + fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b-smoke \\
        --steps 200 --batch 16 --seq 128 --ckpt-dir /tmp/run1

Any ``--arch`` accepts the ``-smoke`` suffix for the reduced config (the
full configs need a real pod; this launcher is mesh-agnostic and runs
the same code under pjit when devices are available).  Restarts resume
from the newest atomic checkpoint, replaying the data stream from the
recorded step — byte-identical to an uninterrupted run (see
tests/test_system.py::test_crash_restart_exact_resume).
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from ..checkpoint import AsyncCheckpointer, latest_step, restore
from ..configs import get_config
from ..data import DataPipeline, SyntheticLM
from ..ft import Watchdog
from ..models import build_model
from ..optim import AdamWConfig, init_opt
from ..train import TrainStepConfig, make_train_step


def run(arch: str, *, steps: int = 100, batch: int = 16, seq: int = 128,
        lr: float = 3e-4, microbatches: int = 1, remat: str = "none",
        ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
        log_every: int = 10, seed: int = 0, watchdog_timeout: float = 600.0):
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = init_opt(params)
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{steps} steps, batch {batch} x seq {seq}")

    step_fn = jax.jit(make_train_step(
        model, AdamWConfig(lr=lr),
        TrainStepConfig(microbatches=microbatches, remat=remat,
                        warmup_steps=max(1, steps // 20), total_steps=steps)))

    start = 0
    ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if ckpt_dir and (resume := latest_step(ckpt_dir)) is not None:
        state, extra = restore(ckpt_dir, resume,
                               {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        start = extra.get("data_step", resume)
        print(f"[train] resumed from step {start}")

    src = SyntheticLM(vocab=cfg.vocab, seed=seed)
    pipe = DataPipeline(src, global_batch=batch, seq=seq, start_step=start)
    wd = Watchdog(timeout_s=watchdog_timeout,
                  on_stall=lambda s, gap: print(
                      f"[watchdog] STALL at step {s} ({gap:.0f}s) — "
                      f"restart from {ckpt_dir or 'nowhere (no ckpt dir!)'}"))

    losses = []
    t0 = time.time()
    try:
        for i in range(start, steps):
            b = next(pipe)
            jb = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, metrics = step_fn(params, opt, jb)
            wd.beat(i)
            losses.append(float(metrics["loss"]))
            if (i + 1) % log_every == 0:
                dt = (time.time() - t0) / max(1, len(losses))
                print(f"  step {i + 1:5d}  loss {losses[-1]:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.2f}  "
                      f"{dt * 1e3:.0f} ms/step")
            if ckpt and (i + 1) % ckpt_every == 0:
                ckpt.save_async(i + 1, {"params": params, "opt": opt},
                                extra={"data_step": i + 1})
    finally:
        pipe.close()
        wd.close()
        if ckpt:
            ckpt.wait()
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    run(a.arch, steps=a.steps, batch=a.batch, seq=a.seq, lr=a.lr,
        microbatches=a.microbatches, remat=a.remat, ckpt_dir=a.ckpt_dir,
        ckpt_every=a.ckpt_every, seed=a.seed)


if __name__ == "__main__":
    main()
