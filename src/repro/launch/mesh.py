"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
data-parallel by default (gradient all-reduce crosses the inter-pod
links), with PP-over-pod available as a §Perf experiment.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (the dry-run must set
``xla_force_host_platform_device_count`` before any jax initialization).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "SINGLE_POD_DEVICES", "MULTI_POD_DEVICES"]

SINGLE_POD_DEVICES = 256
MULTI_POD_DEVICES = 512


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic re-planning, tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes))
