"""Post-compile HLO analysis: collective bytes + roofline terms.

``cost_analysis()`` has no collective accounting, so collective traffic
is parsed from the optimized (post-SPMD-partitioning) HLO text of the
compiled executable, where shapes are already per-device.  Bytes moved
per device are modeled with ring factors:

    all-reduce        2 (N-1)/N x result bytes   (reduce-scatter + all-gather)
    all-gather          (N-1)/N x result bytes
    reduce-scatter      (N-1)   x result bytes   (operand = N x result)
    all-to-all          (N-1)/N x result bytes
    collective-permute        1 x result bytes

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["PEAK_FLOPS", "HBM_BW", "ICI_BW", "CollectiveStats",
           "parse_collectives", "roofline_terms", "dtype_bytes"]

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per-device budget)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def dtype_bytes(dt: str) -> int:
    return _DTYPE_BYTES.get(dt, 4)


@dataclass
class CollectiveStats:
    per_op: Dict[str, float] = field(default_factory=dict)   # modeled bytes
    per_op_count: Dict[str, int] = field(default_factory=dict)
    raw_result_bytes: float = 0.0
    modeled_bytes: float = 0.0                                 # per device

    def add(self, kind: str, bytes_: float, n: int):
        if kind == "all-reduce":
            moved = 2.0 * (n - 1) / max(n, 1) * bytes_
        elif kind == "all-gather":
            moved = (n - 1) / max(n, 1) * bytes_
        elif kind == "reduce-scatter":
            moved = (n - 1) * bytes_
        elif kind == "all-to-all":
            moved = (n - 1) / max(n, 1) * bytes_
        else:                               # collective-permute
            moved = bytes_
        self.per_op[kind] = self.per_op.get(kind, 0.0) + moved
        self.per_op_count[kind] = self.per_op_count.get(kind, 0) + 1
        self.raw_result_bytes += bytes_
        self.modeled_bytes += moved


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum collective traffic from optimized per-device HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        if dt not in _DTYPE_BYTES and not dt[0] in "sfub":
            continue
        size = dtype_bytes(dt)
        if dims:
            for d in dims.split(","):
                size *= int(d)
        n = 2
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                n = int(gi.group(2))
        stats.add(kind, float(size), n)
    return stats


# ----------------------------------------------------------------------
# Trip-count-aware module analysis
# ----------------------------------------------------------------------
# XLA's cost_analysis() counts while-loop bodies ONCE (verified: a
# 10-iteration scan of matmuls reports 1/10th of the unrolled flops), so
# scanned-layer models would under-report flops/bytes/collectives by
# O(layers x microbatches).  The optimized HLO text annotates every while
# with backend_config known_trip_count; this analyzer propagates those
# multipliers down the call tree and accumulates:
#   * flops  — from dot ops (2 * prod(result) * K per contracted dim);
#   * bytes  — operand + output sizes of scheduled instructions
#              (fusion callers, dots, copies — the HBM traffic proxy);
#   * collectives — ring-model bytes as in parse_collectives.

_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*\(?\s*([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[\d,]*\][^\s]*)\s+([\w\-]+)\(")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n[":\s]+"?(\d+)')
_WHILE_BODY_RE = re.compile(r"body=%([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_COND_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_NO_TRAFFIC = {"tuple", "get-tuple-element", "parameter", "constant",
               "bitcast", "after-all", "add-dependency", "while",
               "conditional", "call", "optimization-barrier"}


@dataclass
class ModuleCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: "CollectiveStats" = None  # type: ignore


def _shape_size(dtype: str, dims: str) -> Tuple[int, List[int]]:
    size = dtype_bytes(dtype)
    dl = [int(d) for d in dims.split(",") if d] if dims else []
    n = 1
    for d in dl:
        n *= d
    return size * n, dl


def analyze_hlo(text: str) -> ModuleCost:
    """Trip-count-corrected per-device cost of an optimized HLO module."""
    # ---- pass 1: split computations, build symbol table -----------------
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    shapes: Dict[str, Tuple[str, str]] = {}     # instr -> (dtype, dims)
    for line in text.splitlines():
        m = _COMP_HEADER_RE.match(line.strip()) if not line.startswith(" ") \
            else None
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None and line.strip():
            comps[cur].append(line)
            mi = _INSTR_RE.match(line)
            if mi:
                shapes[mi.group(1)] = (mi.group(2), mi.group(3))

    # ---- pass 2: call graph with multipliers -----------------------------
    mult: Dict[str, float] = {}

    def visit(comp: str, m: float):
        if comp not in comps:
            return
        mult[comp] = max(mult.get(comp, 0.0), m)
        for line in comps[comp]:
            om = _OP_RE.search(line)
            op = om.group(1) if om else ""
            if op == "while":
                tm = _TRIP_RE.search(line)
                trips = float(tm.group(1)) if tm else 1.0
                bm = _WHILE_BODY_RE.search(line)
                if bm:
                    visit(bm.group(1), m * trips)
            elif op == "conditional":
                bm = _COND_BRANCHES_RE.search(line)
                if bm:
                    for b in _OPERAND_RE.findall(bm.group(1)):
                        visit(b, m)
            else:
                cm = _CALLS_RE.search(line)
                if cm:
                    visit(cm.group(1), m)

    if entry is None:
        return ModuleCost(collectives=CollectiveStats())
    visit(entry, 1.0)

    # ---- pass 2b: fusion param traffic overrides -------------------------
    # A dynamic-slice fused into its consumer makes the fusion's operand
    # the FULL stacked array (e.g. the (L, d, ff) scan-invariant weight
    # stack) while the hardware only reads one slice per iteration.  For
    # each fused computation, map param -> touched bytes when the param
    # is consumed exclusively by slicing ops.
    _SLICERS = {"dynamic-slice", "slice", "gather"}
    fusion_param_bytes: Dict[str, Dict[int, float]] = {}
    _PARAM_HDR_RE = re.compile(r"\(([^)]*)\)\s*->")
    for comp, lines in comps.items():
        # param order from the instruction stream: parameters are declared
        # as '%name = type[] parameter(N)'
        param_index: Dict[str, int] = {}
        uses: Dict[str, List[Tuple[str, float]]] = {}
        for line in lines:
            mi = _INSTR_RE.match(line)
            om = _OP_RE.search(line)
            if not mi or not om:
                continue
            name, op = mi.group(1), om.group(1)
            if op == "parameter":
                pm = re.search(r"parameter\((\d+)\)", line)
                if pm:
                    param_index[name] = int(pm.group(1))
                continue
            out_b, _ = _shape_size(mi.group(2), mi.group(3))
            try:
                inner = line.split(op + "(", 1)[1].split(")", 1)[0]
                for onm in _OPERAND_RE.findall(inner):
                    uses.setdefault(onm, []).append((op, out_b))
            except IndexError:
                continue
        overrides: Dict[int, float] = {}
        for pname, idx in param_index.items():
            us = uses.get(pname, [])
            if us and all(op in _SLICERS for op, _ in us):
                overrides[idx] = sum(b for _, b in us)
        if overrides:
            fusion_param_bytes[comp] = overrides

    # ---- pass 3: accumulate costs ----------------------------------------
    cost = ModuleCost(collectives=CollectiveStats())
    _COLL_OPS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                 "collective-permute"}
    for comp, lines in comps.items():
        m = mult.get(comp)
        if m is None:
            continue                       # unreachable helper
        scheduled = not comp.startswith(("wrapped_", "fused"))
        for line in lines:
            mi = _INSTR_RE.match(line)
            om = _OP_RE.search(line)
            if not mi or not om:
                continue
            dtype, dims = mi.group(2), mi.group(3)
            op = om.group(1)
            out_bytes, out_dims = _shape_size(dtype, dims)

            if op == "dot":
                k = 1
                cm = _CONTRACT_RE.search(line)
                opnds = _OPERAND_RE.findall(
                    line.split("dot(")[1].split(")")[0])
                if cm and opnds and opnds[0] in shapes:
                    _, ldims = _shape_size(*shapes[opnds[0]])
                    for ci in (int(c) for c in cm.group(1).split(",") if c):
                        if ci < len(ldims):
                            k *= ldims[ci]
                n_out = 1
                for d in out_dims:
                    n_out *= d
                cost.flops += m * 2.0 * n_out * k
            elif op.replace("-start", "") in _COLL_OPS:
                n = 2
                g = _GROUPS_RE.search(line)
                if g:
                    n = len(g.group(1).split(","))
                else:
                    gi = _GROUPS_IOTA_RE.search(line)
                    if gi:
                        n = int(gi.group(2))
                kind = op.replace("-start", "")
                base = CollectiveStats()
                base.add(kind, float(out_bytes), n)
                cost.collectives.per_op[kind] = (
                    cost.collectives.per_op.get(kind, 0.0)
                    + base.modeled_bytes * m)
                cost.collectives.per_op_count[kind] = (
                    cost.collectives.per_op_count.get(kind, 0) + int(m))
                cost.collectives.raw_result_bytes += out_bytes * m
                cost.collectives.modeled_bytes += base.modeled_bytes * m

            # HBM traffic proxy: operand + output bytes of scheduled ops,
            # with slicing ops counted at their TOUCHED size (a
            # dynamic-slice of the (L, ...) stacked-params tree reads one
            # layer's slice, not the whole stack — counting full operands
            # overstated gemma2 train traffic ~25x).
            if scheduled and op not in _NO_TRAFFIC:
                opnd_sizes = []
                try:
                    inner = line.split(op + "(", 1)[1].split(")", 1)[0]
                    for onm in _OPERAND_RE.findall(inner):
                        if onm in shapes:
                            opnd_sizes.append(_shape_size(*shapes[onm])[0])
                except IndexError:
                    pass
                if op in ("dynamic-slice", "gather", "slice"):
                    traffic = 2.0 * out_bytes          # read + write slice
                elif op == "dynamic-update-slice":
                    upd = opnd_sizes[1] if len(opnd_sizes) > 1 else out_bytes
                    traffic = 2.0 * upd                # read + write update
                elif op in ("scatter", "select-and-scatter"):
                    upd = opnd_sizes[-1] if opnd_sizes else out_bytes
                    traffic = 2.0 * upd + (opnd_sizes[1]
                                           if len(opnd_sizes) > 2 else 0)
                elif op == "fusion":
                    cm = _CALLS_RE.search(line)
                    ov = fusion_param_bytes.get(cm.group(1), {}) if cm else {}
                    traffic = out_bytes
                    for i, ob in enumerate(opnd_sizes):
                        traffic += min(ov.get(i, ob), ob)
                else:
                    traffic = out_bytes + sum(opnd_sizes)
                cost.bytes += m * traffic
    return cost


def roofline_terms(*, flops_per_device: float, bytes_per_device: float,
                   collective_bytes: float) -> Dict[str, float]:
    """The three §Roofline terms, in seconds (per device = per step)."""
    t_compute = flops_per_device / PEAK_FLOPS
    t_memory = bytes_per_device / HBM_BW
    t_collective = collective_bytes / ICI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_collective), key=lambda kv: kv[1])
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "bound": dominant[0],
        "t_bound_s": dominant[1],
    }
