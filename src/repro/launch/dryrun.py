import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh, the sharded step function
(train / prefill / decode per the shape kind), lowers it against
ShapeDtypeStruct inputs (no allocation), compiles, and records:

  * ``memory_analysis()``  — bytes per device (does the cell fit?)
  * ``cost_analysis()``    — HLO FLOPs / bytes for §Roofline
  * collective bytes       — parsed from the optimized HLO

Artifacts land in ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``;
benchmarks and EXPERIMENTS.md read them from there.

Usage:
    python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_config, get_shape, list_archs
from ..core.autotune import choose_train_knobs
from ..dist.sharding import (batch_spec, cache_spec, lm_rules, mesh_context,
                             residual_sharding, zero1_spec)
from ..models import (build_model, decode_specs, params_specs, prefill_specs,
                      train_batch_specs)
from ..optim import AdamWConfig, OptState, init_opt, init_opt_q8
from ..train import TrainStepConfig, make_train_step
from .hlo_analysis import analyze_hlo, parse_collectives, roofline_terms
from .mesh import make_production_mesh

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


def _opt_state_specs(pspecs):
    zeros = jax.eval_shape(init_opt, pspecs)
    return zeros


def _shardings_for(tree_specs, rules, mesh):
    return rules.tree(tree_specs, mesh)


def _opt_shardings(opt_specs: OptState, param_sh, mesh):
    def leaf(sh, spec):
        return zero1_spec(sh, tuple(spec.shape), mesh)
    mu = jax.tree.map(leaf, param_sh, opt_specs.mu)
    nu = jax.tree.map(leaf, param_sh, opt_specs.nu)
    from jax.sharding import NamedSharding, PartitionSpec as P
    return OptState(step=NamedSharding(mesh, P()), mu=mu, nu=nu)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             microbatches: int = 1, remat: str = "full",
             accum_dtype: str = "float32", auto: bool = False,
             q8_moments: bool = False, seq_parallel: bool = False,
             out_dir: Optional[str] = None, verbose: bool = True,
             extra_tag: str = "") -> Dict[str, Any]:
    """Lower+compile one cell; returns (and persists) the record."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_shape = ({"pod": 2, "data": 16, "model": 16}
                  if mesh_kind == "multipod" else {"data": 16, "model": 16})
    plan = None
    if auto and shape.kind == "train":
        plan = choose_train_knobs(cfg, shape, mesh_shape)
        microbatches, remat = plan.microbatches, plan.remat
        accum_dtype = plan.accum_dtype
    ok, why = shape.applicable(cfg)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "microbatches": microbatches, "remat": remat,
        "accum_dtype": accum_dtype, "q8_moments": q8_moments,
        "seq_parallel": seq_parallel,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    if plan is not None:
        record["planned_bytes"] = plan.est_bytes
        record["plan_breakdown"] = {k: round(v / 1e9, 3)
                                    for k, v in plan.breakdown.items()}
    if not ok:
        record["status"] = "skip"
        record["skip_reason"] = why
        _persist(record, out_dir, extra_tag)
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    model = build_model(cfg)
    rules = lm_rules(cfg.family,
                     two_d_experts=(cfg.family == "moe"
                                    and cfg.param_count() > 2e11))
    t0 = time.time()
    import contextlib
    res_ctx = (residual_sharding(("data", "model", None)) if seq_parallel
               else contextlib.nullcontext())
    try:
        with mesh_context(mesh), res_ctx:
            if shape.kind == "train":
                pspecs = params_specs(cfg)
                ospecs = (jax.eval_shape(init_opt_q8, pspecs) if q8_moments
                          else _opt_state_specs(pspecs))
                bspecs = train_batch_specs(cfg, shape)
                p_sh = _shardings_for(pspecs, rules, mesh)
                o_sh = (_q8_opt_shardings(ospecs, p_sh, mesh) if q8_moments
                        else _opt_shardings(ospecs, p_sh, mesh))
                b_sh = batch_spec(bspecs, mesh)
                step = make_train_step(
                    model, AdamWConfig(),
                    TrainStepConfig(microbatches=microbatches, remat=remat,
                                    accum_dtype=accum_dtype,
                                    quantized_moments=q8_moments))
                from jax.sharding import NamedSharding, PartitionSpec as P
                rep = NamedSharding(mesh, P())
                out_specs = jax.eval_shape(step, pspecs, ospecs, bspecs)
                metric_sh = jax.tree.map(lambda _: rep, out_specs[2])
                jitted = jax.jit(step,
                                 in_shardings=(p_sh, o_sh, b_sh),
                                 out_shardings=(p_sh, o_sh, metric_sh),
                                 donate_argnums=(0, 1))
                lowered = jitted.lower(pspecs, ospecs, bspecs)
            elif shape.kind == "prefill":
                pspecs = params_specs(cfg)
                bspecs = prefill_specs(cfg, shape)
                p_sh = _shardings_for(pspecs, rules, mesh)
                b_sh = batch_spec(bspecs, mesh)

                def prefill(params, batch):
                    return model.prefill(params, batch)

                jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh))
                lowered = jitted.lower(pspecs, bspecs)
            else:  # decode
                pspecs = params_specs(cfg)
                tok_specs, cache_specs_ = decode_specs(cfg, shape)
                p_sh = _shardings_for(pspecs, rules, mesh)
                c_sh = cache_spec(cache_specs_, mesh,
                                  seq_shard=(shape.global_batch == 1))
                b_sh = batch_spec({"tokens": tok_specs}, mesh)["tokens"]

                def decode(params, tokens, cache):
                    return model.decode_step(params, tokens, cache)

                from jax.sharding import NamedSharding, PartitionSpec as P
                logits_sh = NamedSharding(
                    mesh, P(("pod", "data") if mesh_kind == "multipod"
                            else "data")
                    if shape.global_batch % mesh.shape.get("data", 1) == 0
                    and shape.global_batch > 1 else P())
                jitted = jax.jit(decode, in_shardings=(p_sh, b_sh, c_sh),
                                 out_shardings=(logits_sh, c_sh),
                                 donate_argnums=(2,))
                lowered = jitted.lower(pspecs, tok_specs, cache_specs_)

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        if os.environ.get("REPRO_SAVE_HLO"):
            import gzip
            hdir = os.path.join(out_dir or ARTIFACTS, "hlo")
            os.makedirs(hdir, exist_ok=True)
            tag2 = f"__{extra_tag}" if extra_tag else ""
            with gzip.open(os.path.join(
                    hdir, f"{arch}__{shape_name}__{mesh_kind}{tag2}.hlo.gz"),
                    "wt") as zf:
                zf.write(hlo)
        # trip-count-aware analysis (XLA cost_analysis counts while bodies
        # once — see hlo_analysis.analyze_hlo)
        mc = analyze_hlo(hlo)
        coll = mc.collectives

        n_dev = mesh.size
        flops_dev = float(mc.flops)
        bytes_dev = float(mc.bytes)
        terms = roofline_terms(flops_per_device=flops_dev,
                               bytes_per_device=bytes_dev,
                               collective_bytes=coll.modeled_bytes)

        record.update({
            "status": "ok",
            "devices": n_dev,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", 0),
            },
            "cost": {"flops_per_device": flops_dev,
                     "bytes_per_device": bytes_dev,
                     "xla_cost_flops_raw": float(cost.get("flops", 0.0)),
                     "xla_cost_bytes_raw": float(
                         cost.get("bytes accessed", 0.0))},
            "collectives": {
                "modeled_bytes_per_device": coll.modeled_bytes,
                "raw_result_bytes": coll.raw_result_bytes,
                "per_op": coll.per_op,
                "per_op_count": coll.per_op_count,
            },
            "roofline": terms,
        })
        if verbose:
            mb = record["memory"]
            print(f"[ok] {arch} x {shape_name} x {mesh_kind} "
                  f"({n_dev} dev): compile {t_compile:.1f}s, "
                  f"args {mb['argument_bytes']/1e9:.2f} GB/dev, "
                  f"temp {mb['temp_bytes']/1e9:.2f} GB/dev, "
                  f"bound={terms['bound']}")
    except Exception as e:  # noqa: BLE001 - record the failure, keep going
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[ERR] {arch} x {shape_name} x {mesh_kind}: "
                  f"{record['error'][:200]}")
    _persist(record, out_dir, extra_tag)
    return record


def _q8_opt_shardings(ospecs, p_sh, mesh):
    """Quantized moments inherit the parameter sharding (int8 tensors are
    param-shaped); row scales drop the trailing dim of the spec."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def q_leaf(sh, _):
        return sh

    def s_leaf(sh, x):
        spec = list(sh.spec)[: max(0, len(x.shape))]
        return NamedSharding(mesh, P(*spec))

    mu_q = jax.tree.map(q_leaf, p_sh, ospecs.mu_q)
    mu_s = jax.tree.map(s_leaf, p_sh, ospecs.mu_s)
    nu_q = jax.tree.map(q_leaf, p_sh, ospecs.nu_q)
    nu_s = jax.tree.map(s_leaf, p_sh, ospecs.nu_s)
    from ..optim import QuantOptState
    return QuantOptState(step=NamedSharding(mesh, P()), mu_q=mu_q, mu_s=mu_s,
                         nu_q=nu_q, nu_s=nu_s)


def _persist(record: Dict[str, Any], out_dir: Optional[str], tag: str = ""):
    out_dir = out_dir or ARTIFACTS
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fn = (f"{record['arch']}__{record['shape']}__{record['mesh']}"
          f"{suffix}.json")
    with open(os.path.join(out_dir, fn), "w") as f:
        json.dump(record, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--auto", action="store_true",
                    help="pick microbatches/remat via core.autotune")
    ap.add_argument("--q8-moments", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--accum-dtype", default="float32")
    ap.add_argument("--out", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = [s.name for s in SHAPES] if args.all or not args.shape \
        else [args.shape]

    n_ok = n_skip = n_err = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                if args.skip_existing:
                    fn = os.path.join(args.out or ARTIFACTS,
                                      f"{arch}__{shape}__{mesh_kind}.json")
                    if os.path.exists(fn):
                        with open(fn) as f:
                            if json.load(f).get("status") == "ok":
                                continue
                rec = run_cell(arch, shape, mesh_kind,
                               microbatches=args.microbatches,
                               remat=args.remat, auto=args.auto,
                               accum_dtype=args.accum_dtype,
                               q8_moments=args.q8_moments,
                               seq_parallel=args.seq_parallel,
                               out_dir=args.out,
                               extra_tag=args.tag)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skip"
                n_err += st == "error"
    print(f"dry-run complete: {n_ok} ok, {n_skip} skip, {n_err} error")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
