"""Qwen2-VL-72B [arXiv:2409.12191; hf] — VLM; the vision frontend is a
STUB (input_specs() provides M-RoPE position ids and merged embeddings).
Backbone: 80L, d_model=8192, GQA kv=8, M-RoPE."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, rope_theta=1e6, qkv_bias=True, mrope=True,
    mlp_kind="silu_gated", norm_kind="rmsnorm",
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-72B-Instruct",
)
