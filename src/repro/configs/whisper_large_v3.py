"""Whisper-large-v3 [arXiv:2212.04356; unverified] — encoder-decoder;
the conv audio frontend is a STUB: input_specs() provides precomputed
1500-frame embeddings (assignment note).  32 encoder + 32 decoder layers,
MHA (kv=20)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_encoder_layers=32, d_model=1280, n_heads=20,
    n_kv_heads=20, d_ff=5120, vocab=51866, encoder_frames=1500,
    mlp_kind="gelu", norm_kind="layernorm",
    source="arXiv:2212.04356; hf:openai/whisper-large-v3",
)
