"""Kimi K2 1T-A32B [arXiv:2501.kimi2; unverified, paper-table] — 61L
trillion-parameter MoE: 384 experts top-8 + 1 shared expert, GQA kv=8.
The assignment pins GQA (not MLA); first layer dense as in DeepSeek-V3
lineage."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=18432, moe_d_ff=2048, vocab=163840, rope_theta=5e4,
    n_experts=384, top_k=8, n_shared_experts=1, first_dense_layers=1,
    mlp_kind="silu_gated", norm_kind="rmsnorm",
    source="arXiv:2501 Kimi K2 tech report (unverified)",
)
