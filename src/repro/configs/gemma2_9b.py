"""Gemma2-9B [arXiv:2408.00118; hf] — local+global alternating attention,
logit softcapping, GQA (kv=8), head_dim=256, sandwich norms."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab=256000, rope_theta=1e4,
    attn_softcap=50.0, logit_softcap=30.0,
    sliding_window=4096, local_global_alternate=True, post_norms=True,
    mlp_kind="silu_gated", norm_kind="rmsnorm", tie_embeddings=True,
    source="arXiv:2408.00118; hf:google/gemma-2-9b",
)
