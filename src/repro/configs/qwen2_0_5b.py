"""Qwen2-0.5B [arXiv:2407.10671; hf] — GQA (kv=2), QKV bias, tied embeddings."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab=151936, rope_theta=1e6, qkv_bias=True, tie_embeddings=True,
    mlp_kind="silu_gated", norm_kind="rmsnorm",
    source="arXiv:2407.10671; hf:Qwen/Qwen2-0.5B",
)
