"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

from typing import Dict, List

from .base import SHAPES, ModelConfig, ShapeSpec
from .gemma2_9b import CONFIG as _gemma2_9b
from .kimi_k2 import CONFIG as _kimi_k2
from .mamba2_780m import CONFIG as _mamba2_780m
from .nemotron4_15b import CONFIG as _nemotron4_15b
from .phi35_moe import CONFIG as _phi35_moe
from .qwen2_0_5b import CONFIG as _qwen2_0_5b
from .qwen2_vl_72b import CONFIG as _qwen2_vl_72b
from .starcoder2_7b import CONFIG as _starcoder2_7b
from .whisper_large_v3 import CONFIG as _whisper_large_v3
from .zamba2_2_7b import CONFIG as _zamba2_2_7b

__all__ = ["ARCHS", "get_config", "get_shape", "list_archs", "cells"]

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in (
        _qwen2_0_5b, _gemma2_9b, _starcoder2_7b, _nemotron4_15b,
        _kimi_k2, _phi35_moe, _whisper_large_v3, _mamba2_780m,
        _qwen2_vl_72b, _zamba2_2_7b,
    )
}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; available: {[s.name for s in SHAPES]}")


def list_archs() -> List[str]:
    return sorted(ARCHS)


def cells() -> List[tuple]:
    """All 40 (arch, shape) cells with applicability verdicts."""
    out = []
    for a in list_archs():
        cfg = ARCHS[a]
        for s in SHAPES:
            ok, why = s.applicable(cfg)
            out.append((a, s.name, ok, why))
    return out
