"""Zamba2-2.7B [arXiv:2411.15242; hf] — hybrid: 54 Mamba2 layers with a
SHARED full-attention block invoked every 6 layers (weights reused)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, rope_theta=1e4,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    conv_kernel=4, shared_attn_every=6,
    mlp_kind="silu_gated", norm_kind="rmsnorm", tie_embeddings=True,
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B",
)
