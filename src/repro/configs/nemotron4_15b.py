"""Nemotron-4-15B [arXiv:2402.16819; unverified] — GQA (kv=8),
squared-ReLU non-gated MLP."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=24576,
    vocab=256000, rope_theta=1e4,
    mlp_kind="sq_relu", norm_kind="layernorm",
    source="arXiv:2402.16819 (unverified)",
)
