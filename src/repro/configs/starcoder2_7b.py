"""StarCoder2-7B [arXiv:2402.19173; hf] — GQA (kv=4), RoPE, non-gated
GELU MLP with biases, LayerNorm."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432,
    vocab=49152, rope_theta=1e5, qkv_bias=True,
    mlp_kind="gelu", norm_kind="layernorm",
    source="arXiv:2402.19173; hf:bigcode/starcoder2-7b",
)
