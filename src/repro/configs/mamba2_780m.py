"""Mamba2-780m [arXiv:2405.21060; unverified] — attention-free SSD
(state-space duality), 48L, d_model=1536, state=128, headdim=64."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, d_ff=0, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    conv_kernel=4, norm_kind="rmsnorm", tie_embeddings=True,
    source="arXiv:2405.21060; hf:state-spaces/mamba2-780m",
)
