"""Architecture configs (one module per assigned arch) + shape registry."""

from .base import SHAPES, ModelConfig, ShapeSpec
from .registry import ARCHS, cells, get_config, get_shape, list_archs

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "ARCHS", "get_config",
           "get_shape", "list_archs", "cells"]
