"""Model configuration schema for the 10 assigned architectures.

One :class:`ModelConfig` describes any member of the zoo: dense decoder
LMs, MoE LMs, SSM (Mamba2), hybrid (Zamba2), encoder-decoder (Whisper)
and VLM backbones.  Family-specific fields are simply unused by other
families.  ``reduced()`` derives the CPU-smoke-test variant of a config.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES"]


@dataclass(frozen=True)
class ModelConfig:
    # identity ---------------------------------------------------------
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec
    # transformer core ---------------------------------------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0               # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab: int = 0
    # attention flavour --------------------------------------------------
    rope_theta: float = 1e6
    qkv_bias: bool = False          # qwen2
    attn_softcap: float = 0.0       # gemma2: 50.0
    logit_softcap: float = 0.0      # gemma2: 30.0
    sliding_window: int = 0         # gemma2 local layers: 4096
    local_global_alternate: bool = False   # gemma2: even layers local
    post_norms: bool = False        # gemma2 sandwich norms
    mrope: bool = False             # qwen2-vl M-RoPE (3D positions)
    # MLP flavour ---------------------------------------------------------
    mlp_kind: str = "silu_gated"    # silu_gated | gelu | sq_relu
    norm_kind: str = "rmsnorm"      # rmsnorm | layernorm
    tie_embeddings: bool = False
    # MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0       # kimi-k2: 1 shared expert
    moe_d_ff: int = 0               # per-expert FF width (0 -> d_ff)
    first_dense_layers: int = 0     # kimi-k2: first layer dense
    # SSM (Mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # hybrid (zamba2) ------------------------------------------------------
    shared_attn_every: int = 0      # one shared attention block every N
    # encoder-decoder (whisper) --------------------------------------------
    n_encoder_layers: int = 0
    encoder_frames: int = 1500      # stub conv frontend output length
    # numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # notes ------------------------------------------------------------------
    source: str = ""

    # ---------------------------------------------------------------
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def ssm_heads(self) -> int:
        return self.d_inner() // self.ssm_head_dim

    def sub_quadratic(self) -> bool:
        """True when 500k-token decode is admissible (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    def has_decoder(self) -> bool:
        return True   # all assigned archs decode (whisper via its decoder)

    # ---------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameters (for MODEL_FLOPS and the docs)."""
        d, hd = self.d_model, self.hd()
        if self.family in ("dense", "moe", "encdec"):
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        else:
            attn = 0
        per_layer = 0
        if self.family in ("dense", "encdec"):
            mlp = d * self.d_ff * (3 if self.mlp_kind == "silu_gated" else 2)
            per_layer = attn + mlp + 2 * d
        elif self.family == "moe":
            moe = (d * self.n_experts * 1                       # router
                   + self.n_experts * d * self.expert_ff() * 3
                   + self.n_shared_experts * d * self.expert_ff() * 3)
            per_layer = attn + moe + 2 * d
        elif self.family in ("ssm", "hybrid"):
            di, N, H = self.d_inner(), self.ssm_state, self.ssm_heads()
            groups = 1
            ssm = (d * (2 * di + 2 * groups * N + H)            # in_proj
                   + self.conv_kernel * (di + 2 * groups * N)   # conv
                   + di * d + 2 * H + di)                       # out_proj, A/D, norm
            per_layer = ssm + 2 * d
        total = self.n_layers * per_layer
        if self.family == "hybrid" and self.shared_attn_every:
            shared_attn = (d * hd * (self.n_heads + 2 * self.n_kv_heads)
                           + self.n_heads * hd * d
                           + d * self.d_ff * 3 + 4 * d)
            total += shared_attn
        if self.family == "encdec":
            # decoder self+cross attention + mlp
            dec = self.n_layers * (2 * attn + d * self.d_ff * 2 + 3 * d)
            enc = self.n_encoder_layers * (attn + d * self.d_ff * 2 + 2 * d)
            total = enc + dec
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(total)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        cfg_dense = replace(self, n_experts=0, family="dense",
                            d_ff=self.expert_ff())
        attn_part = cfg_dense.param_count() - self.vocab * d * (1 if self.tie_embeddings else 2) \
            - self.n_layers * cfg_dense.d_ff * d * 3
        active_moe = self.n_layers * (
            d * self.n_experts
            + (self.top_k + self.n_shared_experts) * d * self.expert_ff() * 3)
        return int(attn_part + active_moe
                   + self.vocab * d * (1 if self.tie_embeddings else 2))

    # ---------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """A smoke-test-sized config of the same family (small layers,
        few experts, tiny vocab), runnable on CPU in seconds."""
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4) or 2,
            d_model=64,
            n_heads=min(self.n_heads, 4) or 4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16 if self.head_dim else 0,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_d_ff=64 if self.n_experts else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=32,
            shared_attn_every=min(self.shared_attn_every, 2),
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_frames=32 if self.n_encoder_layers else 1500,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            dtype="float32", param_dtype="float32",
        )


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    def applicable(self, cfg: ModelConfig) -> Tuple[bool, str]:
        if self.name == "long_500k" and not cfg.sub_quadratic():
            return False, ("full-attention architecture: 524288-token decode "
                           "requires sub-quadratic attention (DESIGN.md §4)")
        return True, ""


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", seq_len=4096, global_batch=256, kind="train"),
    ShapeSpec("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    ShapeSpec("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    ShapeSpec("long_500k", seq_len=524288, global_batch=1, kind="decode"),
)
