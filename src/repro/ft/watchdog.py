"""Heartbeat watchdog: detects a hung training loop and triggers recovery.

The training loop calls ``beat(step)``; a daemon thread fires
``on_stall`` if no beat arrives within ``timeout_s``.  On a real cluster
the callback escalates to the job controller (restart from the last
atomic checkpoint, ``repro.checkpoint``); in tests it is a plain hook.
The heartbeat is also mirrored to a file so an external supervisor can
watch a whole fleet of hosts with no RPC dependency.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Callable, Optional

__all__ = ["Watchdog"]


class Watchdog:
    def __init__(self, *, timeout_s: float = 300.0,
                 on_stall: Optional[Callable[[int, float], None]] = None,
                 heartbeat_file: Optional[str] = None,
                 poll_s: float = 1.0):
        self.timeout_s = timeout_s
        self.on_stall = on_stall
        self.heartbeat_file = heartbeat_file
        self.poll_s = poll_s
        self._last = time.monotonic()
        self._step = 0
        self._stalled = False
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self._thread.start()

    def beat(self, step: int):
        self._last = time.monotonic()
        self._step = step
        self._stalled = False
        if self.heartbeat_file:
            tmp = self.heartbeat_file + ".tmp"
            with open(tmp, "w") as f:
                f.write(f"{step} {time.time()}")
            os.replace(tmp, self.heartbeat_file)

    @property
    def stalled(self) -> bool:
        return self._stalled

    def _watch(self):
        while not self._stop.wait(self.poll_s):
            gap = time.monotonic() - self._last
            if gap > self.timeout_s and not self._stalled:
                self._stalled = True
                if self.on_stall:
                    self.on_stall(self._step, gap)

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
