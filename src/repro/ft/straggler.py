"""Straggler detection: per-host step-time EWMA vs fleet median.

On a real pod each host reports its step wall-time; here the detector is
a pure function over the report vector so it is testable and usable in
simulation.  A host whose EWMA exceeds ``threshold`` x the fleet median
for ``patience`` consecutive windows is flagged; the launcher's policy
decides between (a) ignoring (transient), (b) excluding the host and
re-planning the mesh (``repro.ft.elastic``), or (c) checkpoint-restart.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["StragglerDetector", "StragglerReport"]


@dataclass
class StragglerReport:
    step: int
    flagged: List[int]
    ewma: np.ndarray
    median: float


class StragglerDetector:
    def __init__(self, n_hosts: int, *, alpha: float = 0.3,
                 threshold: float = 1.5, patience: int = 3):
        self.n_hosts = n_hosts
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self._ewma = np.zeros(n_hosts)
        self._strikes = np.zeros(n_hosts, np.int64)
        self._step = 0

    def update(self, step_times: Sequence[float]) -> StragglerReport:
        t = np.asarray(step_times, np.float64)
        assert t.shape == (self.n_hosts,)
        if self._step == 0:
            self._ewma = t.copy()
        else:
            self._ewma = self.alpha * t + (1 - self.alpha) * self._ewma
        med = float(np.median(self._ewma))
        slow = self._ewma > self.threshold * med
        self._strikes = np.where(slow, self._strikes + 1, 0)
        flagged = np.nonzero(self._strikes >= self.patience)[0].tolist()
        self._step += 1
        return StragglerReport(step=self._step, flagged=flagged,
                               ewma=self._ewma.copy(), median=med)
