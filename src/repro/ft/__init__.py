"""Fault tolerance: watchdog, straggler detection, elastic re-planning."""

from .elastic import ElasticPlan, largest_pow2_leq, replan
from .straggler import StragglerDetector, StragglerReport
from .watchdog import Watchdog

__all__ = ["Watchdog", "StragglerDetector", "StragglerReport",
           "ElasticPlan", "replan", "largest_pow2_leq"]
