"""Elastic scaling: re-plan the mesh after node loss / fleet resize.

COSMOS's compositional argument applies directly (DESIGN.md §2): the
per-component characterization (regions over TP degree x microbatch) is
a property of the MODEL, not of the fleet — so on a mesh change only the
LP (milliseconds) and the mapped compiles (a handful) re-run, not the
characterization sweep.  ``replan`` returns the new mesh shape plus which
knob re-mapping is required; the launcher feeds it to
``repro.core.autotune.replan_for_mesh``.

Policy: keep the model axis as large as the surviving chip count allows
(TP degree is a memory-fit constraint), give the remainder to data.
Both axes stay powers of two (the paper's port constraint, for the same
bank-selection reason).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

__all__ = ["ElasticPlan", "replan", "largest_pow2_leq"]


def largest_pow2_leq(n: int) -> int:
    if n < 1:
        return 0
    return 1 << (n.bit_length() - 1)


@dataclass(frozen=True)
class ElasticPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    usable_devices: int
    dropped_devices: int
    batch_scale: float            # global batch multiplier (DP shrink)
    needs_resharding: bool        # TP degree changed -> params reshard
    note: str = ""


def replan(old_shape: Tuple[int, ...], axis_names: Tuple[str, ...],
           surviving_devices: int, *, min_model: int = 1,
           keep_model_axis: bool = True) -> ElasticPlan:
    """Compute the new mesh after failures leave ``surviving_devices``."""
    old_total = 1
    for s in old_shape:
        old_total *= s
    usable = largest_pow2_leq(surviving_devices)
    if usable < 1:
        raise ValueError("no usable devices")
    shape = dict(zip(axis_names, old_shape))
    model = shape.get("model", 1)
    if keep_model_axis and usable >= model:
        new_model = model
    else:
        new_model = max(min_model, largest_pow2_leq(usable))
    rest = usable // new_model
    if "pod" in shape and shape["pod"] > 1 and rest >= shape["pod"]:
        new_pod = shape["pod"]
        new_data = rest // new_pod
    else:
        new_pod = 1
        new_data = rest
    if "pod" in shape:
        new_shape = (new_pod, new_data, new_model)
    else:
        new_shape = (new_data, new_model)
    new_total = usable
    return ElasticPlan(
        old_shape=tuple(old_shape), new_shape=new_shape,
        axis_names=tuple(axis_names), usable_devices=usable,
        dropped_devices=old_total - surviving_devices,
        batch_scale=new_total / old_total * (model / new_model),
        needs_resharding=(new_model != model),
        note=("TP kept; DP shrinks, global batch scales" if new_model == model
              else "TP degree changed; COSMOS re-maps knobs, params reshard"))
